//! Runtime-dispatched SIMD microkernels under the blocked kernel core.
//!
//! Zero external deps: explicit `std::arch` intrinsics — AVX2+FMA on
//! x86_64 (checked once at runtime via `is_x86_feature_detected!`),
//! NEON on aarch64 (baseline there), and a lane-emulating scalar
//! fallback that is itself the reference spec.  `linalg/blocked.rs`
//! calls these per row/tile; `HostBackend` inherits them everywhere.
//!
//! # Determinism by construction (DESIGN.md §11)
//!
//! The repo-wide contract is that every kernel is bit-identical across
//! backends, thread counts, and tile sizes.  SIMD joins that contract
//! through two arguments:
//!
//! 1. **Vectorize the non-reduction axis.**  For gram-shaped updates
//!    (`acc[q] += a * b[q]`) and `xt_v` the vector lanes span *output
//!    elements*, not the reduction.  Each output accumulator still sees
//!    rows 0..n ascending, so the summation order is exactly the naive
//!    oracle's.  FMA does not perturb bits here: every operand is an
//!    f32 value widened to f64 (or a product of two such), so the
//!    product of two f32-valued f64s has <= 48 significant bits and is
//!    exact in f64 — `fma(a, b, acc)` rounds once on an exact product,
//!    which equals `a*b + acc` computed with a separate rounded
//!    multiply.  This exactness argument is a **precondition**: these
//!    microkernels are only bit-stable for inputs that are widened
//!    f32s, which is the only way the kernel core calls them.
//!
//! 2. **Fixed virtual lane width for reductions.**  Row-dot kernels
//!    (`mat_vec`, `predict_proba`, residual/IRLS eta) cannot avoid a
//!    reordered reduction, so the *spec itself* is lane-shaped:
//!    element `j` accumulates into f64 partial lane `j % 8` (ascending
//!    within each lane) and the 8 lanes are folded left-to-right from
//!    0.0 at the end.  [`dot8_scalar`] is the reference; the AVX2 and
//!    NEON paths implement the identical lane mapping, so results are
//!    bit-identical across ISA, `--kernel-threads`, and tile sizes.
//!    The naive oracle (`linalg::mat_vec`) implements the same spec.
//!
//! # Dispatch ladder
//!
//! `--simd` CLI knob > `NEXUS_SIMD` env > `auto`.  `auto` picks the
//! best ISA the CPU supports; `off` forces the scalar spec; `avx2` /
//! `neon` force an ISA for testing and fall back to scalar (with a
//! one-time stderr warning) when unsupported.  The resolved
//! [`Dispatch`] is carried in `KernelOpts`, so tests can pin a path
//! without touching process globals.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::error::{NexusError, Result};
use crate::util::env as envknob;

/// Virtual lane width of the fixed-lane dot-product spec: 8 f64
/// partial sums, folded left-to-right at the end.
pub const DOT_LANES: usize = 8;

/// User-facing SIMD policy (`--simd` / `NEXUS_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the best instruction set this CPU supports.
    Auto,
    /// Force the scalar reference path.
    Off,
    /// Force AVX2+FMA (testing); falls back to scalar if unsupported.
    ForceAvx2,
    /// Force NEON (testing); falls back to scalar if unsupported.
    ForceNeon,
}

impl SimdMode {
    /// Parse a knob string (`auto` | `off` | `avx2` | `neon`).
    pub fn parse(s: &str) -> Result<SimdMode> {
        match s {
            "auto" => Ok(SimdMode::Auto),
            "off" | "scalar" => Ok(SimdMode::Off),
            "avx2" => Ok(SimdMode::ForceAvx2),
            "neon" => Ok(SimdMode::ForceNeon),
            other => Err(NexusError::Config(format!(
                "unknown simd mode '{other}' (expected auto|off|avx2|neon)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Off => "off",
            SimdMode::ForceAvx2 => "avx2",
            SimdMode::ForceNeon => "neon",
        }
    }
}

/// Resolved instruction set for one kernel call.
///
/// Invariant: `Avx2` / `Neon` values are only produced by
/// [`dispatch_for`] after runtime feature detection succeeds, which is
/// what makes the `unsafe` ISA entry points below sound to call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    Scalar,
    Avx2,
    Neon,
}

impl Dispatch {
    pub fn name(&self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2 => "avx2",
            Dispatch::Neon => "neon",
        }
    }
}

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        return is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
    }
    #[allow(unreachable_code)]
    false
}

fn neon_available() -> bool {
    // NEON (asimd) is part of the aarch64 baseline.
    cfg!(target_arch = "aarch64")
}

/// Resolve a policy to the instruction set actually used, warning once
/// to stderr when a forced ISA is unavailable on this machine.
pub fn dispatch_for(mode: SimdMode) -> Dispatch {
    match mode {
        SimdMode::Off => Dispatch::Scalar,
        SimdMode::Auto => {
            if avx2_available() {
                Dispatch::Avx2
            } else if neon_available() {
                Dispatch::Neon
            } else {
                Dispatch::Scalar
            }
        }
        SimdMode::ForceAvx2 => {
            if avx2_available() {
                Dispatch::Avx2
            } else {
                envknob::warn_once(
                    "simd-force-avx2",
                    "simd mode 'avx2' requested but AVX2+FMA is unavailable on this CPU; \
                     falling back to scalar",
                );
                Dispatch::Scalar
            }
        }
        SimdMode::ForceNeon => {
            if neon_available() {
                Dispatch::Neon
            } else {
                envknob::warn_once(
                    "simd-force-neon",
                    "simd mode 'neon' requested but NEON is unavailable on this CPU; \
                     falling back to scalar",
                );
                Dispatch::Scalar
            }
        }
    }
}

const MODE_UNSET: u8 = u8::MAX;

fn mode_code(m: SimdMode) -> u8 {
    match m {
        SimdMode::Auto => 0,
        SimdMode::Off => 1,
        SimdMode::ForceAvx2 => 2,
        SimdMode::ForceNeon => 3,
    }
}

fn code_mode(c: u8) -> Option<SimdMode> {
    match c {
        0 => Some(SimdMode::Auto),
        1 => Some(SimdMode::Off),
        2 => Some(SimdMode::ForceAvx2),
        3 => Some(SimdMode::ForceNeon),
        _ => None,
    }
}

static CLI_MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Set the process-global SIMD policy (the `--simd` / `RunConfig.simd`
/// knob).  `Auto` defers to `NEXUS_SIMD`, then hardware detection, so
/// setting the default config value does not mask the env knob.
pub fn set_simd_mode(m: SimdMode) {
    CLI_MODE.store(mode_code(m), Ordering::Relaxed);
}

fn env_mode() -> SimdMode {
    static V: OnceLock<SimdMode> = OnceLock::new();
    *V.get_or_init(|| match std::env::var("NEXUS_SIMD") {
        Err(_) => SimdMode::Auto,
        Ok(s) => SimdMode::parse(&s).unwrap_or_else(|_| {
            envknob::warn_once(
                "NEXUS_SIMD",
                &format!("NEXUS_SIMD={s:?} is not auto|off|avx2|neon; falling back to auto"),
            );
            SimdMode::Auto
        }),
    })
}

/// Current policy: CLI knob > `NEXUS_SIMD` env > auto.
pub fn current_mode() -> SimdMode {
    match code_mode(CLI_MODE.load(Ordering::Relaxed)) {
        Some(SimdMode::Auto) | None => env_mode(),
        Some(m) => m,
    }
}

/// Instruction set the next kernel call will use.
pub fn current_dispatch() -> Dispatch {
    dispatch_for(current_mode())
}

// ---------------------------------------------------------------------
// dot8 — the fixed-lane row dot (reduction kernel)
// ---------------------------------------------------------------------

/// Reference implementation of the fixed-lane dot product — this IS
/// the spec.  Element `j` accumulates `a[j] as f64 * b[j] as f64` into
/// lane `j % 8` (within-lane order ascending); lanes fold left-to-right
/// from 0.0.  Length mismatch truncates to the shorter slice (shape
/// checks live in the callers).
pub fn dot8_scalar(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f64; DOT_LANES];
    for (j, (&x, &w)) in a.iter().zip(b).enumerate() {
        lanes[j % DOT_LANES] += x as f64 * w as f64;
    }
    let mut s = 0.0f64;
    for &l in &lanes {
        s += l;
    }
    s
}

/// Fixed-lane dot product of two f32 slices in f64.  Every dispatch
/// path implements the [`dot8_scalar`] spec bit-for-bit.
#[inline]
pub fn dot8(dsp: Dispatch, a: &[f32], b: &[f32]) -> f64 {
    match dsp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Dispatch::Avx2 is only constructed after runtime
        // detection of avx2+fma (see `Dispatch` invariant).
        Dispatch::Avx2 => unsafe { dot8_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Dispatch::Neon => unsafe { dot8_neon(a, b) },
        _ => dot8_scalar(a, b),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot8_avx2(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    // acc0 holds lanes 0..4, acc1 lanes 4..8.  Within a lane the FMA
    // is exact-product + add (operands are widened f32s), so each lane
    // matches the scalar spec bitwise.
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut j = 0usize;
    while j + DOT_LANES <= n {
        let av = _mm256_loadu_ps(a.as_ptr().add(j));
        let bv = _mm256_loadu_ps(b.as_ptr().add(j));
        let a_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(av));
        let a_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(av));
        let b_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(bv));
        let b_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(bv));
        acc0 = _mm256_fmadd_pd(a_lo, b_lo, acc0);
        acc1 = _mm256_fmadd_pd(a_hi, b_hi, acc1);
        j += DOT_LANES;
    }
    let mut lanes = [0.0f64; DOT_LANES];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
    // Remainder: j is a multiple of 8 here, so j % 8 lands elements in
    // the same lanes the spec assigns.
    while j < n {
        lanes[j % DOT_LANES] += *a.get_unchecked(j) as f64 * *b.get_unchecked(j) as f64;
        j += 1;
    }
    let mut s = 0.0f64;
    for &l in &lanes {
        s += l;
    }
    s
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot8_neon(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::aarch64::*;
    let n = a.len().min(b.len());
    // Four 2-wide f64 accumulators = lane pairs (0,1)(2,3)(4,5)(6,7).
    let mut acc = [vdupq_n_f64(0.0); 4];
    let mut j = 0usize;
    while j + DOT_LANES <= n {
        let a0 = vld1q_f32(a.as_ptr().add(j));
        let a1 = vld1q_f32(a.as_ptr().add(j + 4));
        let b0 = vld1q_f32(b.as_ptr().add(j));
        let b1 = vld1q_f32(b.as_ptr().add(j + 4));
        acc[0] = vfmaq_f64(
            acc[0],
            vcvt_f64_f32(vget_low_f32(a0)),
            vcvt_f64_f32(vget_low_f32(b0)),
        );
        acc[1] = vfmaq_f64(acc[1], vcvt_high_f64_f32(a0), vcvt_high_f64_f32(b0));
        acc[2] = vfmaq_f64(
            acc[2],
            vcvt_f64_f32(vget_low_f32(a1)),
            vcvt_f64_f32(vget_low_f32(b1)),
        );
        acc[3] = vfmaq_f64(acc[3], vcvt_high_f64_f32(a1), vcvt_high_f64_f32(b1));
        j += DOT_LANES;
    }
    let mut lanes = [0.0f64; DOT_LANES];
    vst1q_f64(lanes.as_mut_ptr(), acc[0]);
    vst1q_f64(lanes.as_mut_ptr().add(2), acc[1]);
    vst1q_f64(lanes.as_mut_ptr().add(4), acc[2]);
    vst1q_f64(lanes.as_mut_ptr().add(6), acc[3]);
    while j < n {
        lanes[j % DOT_LANES] += *a.get_unchecked(j) as f64 * *b.get_unchecked(j) as f64;
        j += 1;
    }
    let mut s = 0.0f64;
    for &l in &lanes {
        s += l;
    }
    s
}

// ---------------------------------------------------------------------
// widen — f32 panel -> f64 scratch, optional f32 scale (element-wise)
// ---------------------------------------------------------------------

/// `dst[q] = (src[q] * scale) as f64` (the multiply happens in f32
/// first — the oracle's rounding) or a plain widen when `scale` is
/// `None`.  Element-wise, so every dispatch path is trivially
/// bit-identical.  Truncates to the shorter of `dst` / `src`.
#[inline]
pub fn widen(dsp: Dispatch, dst: &mut [f64], src: &[f32], scale: Option<f32>) {
    match dsp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `Dispatch` invariant.
        Dispatch::Avx2 => unsafe { widen_avx2(dst, src, scale) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Dispatch::Neon => unsafe { widen_neon(dst, src, scale) },
        _ => widen_scalar(dst, src, scale),
    }
}

fn widen_scalar(dst: &mut [f64], src: &[f32], scale: Option<f32>) {
    match scale {
        Some(m) => {
            for (o, &v) in dst.iter_mut().zip(src) {
                *o = (v * m) as f64;
            }
        }
        None => {
            for (o, &v) in dst.iter_mut().zip(src) {
                *o = v as f64;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn widen_avx2(dst: &mut [f64], src: &[f32], scale: Option<f32>) {
    use std::arch::x86_64::*;
    let n = dst.len().min(src.len());
    let mut q = 0usize;
    match scale {
        Some(m) => {
            let mv = _mm_set1_ps(m);
            while q + 4 <= n {
                let sv = _mm_mul_ps(_mm_loadu_ps(src.as_ptr().add(q)), mv);
                _mm256_storeu_pd(dst.as_mut_ptr().add(q), _mm256_cvtps_pd(sv));
                q += 4;
            }
            while q < n {
                *dst.get_unchecked_mut(q) = (*src.get_unchecked(q) * m) as f64;
                q += 1;
            }
        }
        None => {
            while q + 4 <= n {
                let sv = _mm_loadu_ps(src.as_ptr().add(q));
                _mm256_storeu_pd(dst.as_mut_ptr().add(q), _mm256_cvtps_pd(sv));
                q += 4;
            }
            while q < n {
                *dst.get_unchecked_mut(q) = *src.get_unchecked(q) as f64;
                q += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn widen_neon(dst: &mut [f64], src: &[f32], scale: Option<f32>) {
    use std::arch::aarch64::*;
    let n = dst.len().min(src.len());
    let mut q = 0usize;
    match scale {
        Some(m) => {
            let mv = vdupq_n_f32(m);
            while q + 4 <= n {
                let sv = vmulq_f32(vld1q_f32(src.as_ptr().add(q)), mv);
                vst1q_f64(dst.as_mut_ptr().add(q), vcvt_f64_f32(vget_low_f32(sv)));
                vst1q_f64(dst.as_mut_ptr().add(q + 2), vcvt_high_f64_f32(sv));
                q += 4;
            }
            while q < n {
                *dst.get_unchecked_mut(q) = (*src.get_unchecked(q) * m) as f64;
                q += 1;
            }
        }
        None => {
            while q + 4 <= n {
                let sv = vld1q_f32(src.as_ptr().add(q));
                vst1q_f64(dst.as_mut_ptr().add(q), vcvt_f64_f32(vget_low_f32(sv)));
                vst1q_f64(dst.as_mut_ptr().add(q + 2), vcvt_high_f64_f32(sv));
                q += 4;
            }
            while q < n {
                *dst.get_unchecked_mut(q) = *src.get_unchecked(q) as f64;
                q += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------
// gram_panel_update — one row's outer-product update for a tile
// ---------------------------------------------------------------------

/// `acc[p*db + q] += abuf[p] * pbuf[q]` for the whole `da x db` tile
/// (da = abuf.len(), db = pbuf.len(), acc.len() >= da*db).  Lanes span
/// `q` — the non-reduction axis — so each `acc` element accumulates in
/// the caller's row order; FMA is exact on these widened-f32 operands
/// (see module docs), making every path bit-identical.
#[inline]
pub fn gram_panel_update(dsp: Dispatch, acc: &mut [f64], abuf: &[f64], pbuf: &[f64]) {
    debug_assert!(acc.len() >= abuf.len() * pbuf.len());
    match dsp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `Dispatch` invariant.
        Dispatch::Avx2 => unsafe { gram_panel_avx2(acc, abuf, pbuf) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Dispatch::Neon => unsafe { gram_panel_neon(acc, abuf, pbuf) },
        _ => gram_panel_scalar(acc, abuf, pbuf),
    }
}

fn gram_panel_scalar(acc: &mut [f64], abuf: &[f64], pbuf: &[f64]) {
    let db = pbuf.len();
    for (p, &a) in abuf.iter().enumerate() {
        let dst = &mut acc[p * db..(p + 1) * db];
        for (o, &b) in dst.iter_mut().zip(pbuf) {
            *o += a * b;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gram_panel_avx2(acc: &mut [f64], abuf: &[f64], pbuf: &[f64]) {
    use std::arch::x86_64::*;
    let db = pbuf.len();
    for (p, &a) in abuf.iter().enumerate() {
        let row = acc.as_mut_ptr().add(p * db);
        let av = _mm256_set1_pd(a);
        let mut q = 0usize;
        while q + 4 <= db {
            let bv = _mm256_loadu_pd(pbuf.as_ptr().add(q));
            let ov = _mm256_loadu_pd(row.add(q));
            _mm256_storeu_pd(row.add(q), _mm256_fmadd_pd(av, bv, ov));
            q += 4;
        }
        while q < db {
            *row.add(q) += a * *pbuf.get_unchecked(q);
            q += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn gram_panel_neon(acc: &mut [f64], abuf: &[f64], pbuf: &[f64]) {
    use std::arch::aarch64::*;
    let db = pbuf.len();
    for (p, &a) in abuf.iter().enumerate() {
        let row = acc.as_mut_ptr().add(p * db);
        let av = vdupq_n_f64(a);
        let mut q = 0usize;
        while q + 2 <= db {
            let bv = vld1q_f64(pbuf.as_ptr().add(q));
            let ov = vld1q_f64(row.add(q));
            vst1q_f64(row.add(q), vfmaq_f64(ov, av, bv));
            q += 2;
        }
        if q < db {
            *row.add(q) += a * *pbuf.get_unchecked(q);
        }
    }
}

// ---------------------------------------------------------------------
// axpy_widen — xt_v's inner update (lanes span output columns)
// ---------------------------------------------------------------------

/// `acc[q] += a * (b[q] as f64)` — the `xt_v` per-row update.  Lanes
/// span `q` (output columns); `a` is a widened f32 (`v[i] as f64`), so
/// products are exact and FMA matches mul+add bitwise.  Truncates to
/// the shorter of `acc` / `b`.
#[inline]
pub fn axpy_widen(dsp: Dispatch, acc: &mut [f64], a: f64, b: &[f32]) {
    match dsp {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `Dispatch` invariant.
        Dispatch::Avx2 => unsafe { axpy_widen_avx2(acc, a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Dispatch::Neon => unsafe { axpy_widen_neon(acc, a, b) },
        _ => axpy_widen_scalar(acc, a, b),
    }
}

fn axpy_widen_scalar(acc: &mut [f64], a: f64, b: &[f32]) {
    for (o, &x) in acc.iter_mut().zip(b) {
        *o += a * x as f64;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_widen_avx2(acc: &mut [f64], a: f64, b: &[f32]) {
    use std::arch::x86_64::*;
    let n = acc.len().min(b.len());
    let av = _mm256_set1_pd(a);
    let mut q = 0usize;
    while q + 4 <= n {
        let bv = _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(q)));
        let ov = _mm256_loadu_pd(acc.as_ptr().add(q));
        _mm256_storeu_pd(acc.as_mut_ptr().add(q), _mm256_fmadd_pd(av, bv, ov));
        q += 4;
    }
    while q < n {
        *acc.get_unchecked_mut(q) += a * *b.get_unchecked(q) as f64;
        q += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_widen_neon(acc: &mut [f64], a: f64, b: &[f32]) {
    use std::arch::aarch64::*;
    let n = acc.len().min(b.len());
    let av = vdupq_n_f64(a);
    let mut q = 0usize;
    while q + 4 <= n {
        let bv = vld1q_f32(b.as_ptr().add(q));
        let lo = vcvt_f64_f32(vget_low_f32(bv));
        let hi = vcvt_high_f64_f32(bv);
        let o0 = vld1q_f64(acc.as_ptr().add(q));
        let o1 = vld1q_f64(acc.as_ptr().add(q + 2));
        vst1q_f64(acc.as_mut_ptr().add(q), vfmaq_f64(o0, av, lo));
        vst1q_f64(acc.as_mut_ptr().add(q + 2), vfmaq_f64(o1, av, hi));
        q += 4;
    }
    while q < n {
        *acc.get_unchecked_mut(q) += a * *b.get_unchecked(q) as f64;
        q += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut r = Pcg32::new(seed);
        let a: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
        let b: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
        (a, b)
    }

    const LENS: [usize; 13] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 100, 257];

    #[test]
    fn mode_parse_roundtrip_and_rejects() {
        for m in [SimdMode::Auto, SimdMode::Off, SimdMode::ForceAvx2, SimdMode::ForceNeon] {
            assert_eq!(SimdMode::parse(m.name()).unwrap(), m);
        }
        assert_eq!(SimdMode::parse("scalar").unwrap(), SimdMode::Off);
        assert!(SimdMode::parse("sse9").is_err());
    }

    #[test]
    fn dispatch_resolution_is_sane() {
        assert_eq!(dispatch_for(SimdMode::Off), Dispatch::Scalar);
        // Auto resolves to whatever the machine has; forcing an
        // unsupported ISA degrades to scalar rather than crashing.
        let _ = dispatch_for(SimdMode::Auto);
        let _ = dispatch_for(SimdMode::ForceAvx2);
        let _ = dispatch_for(SimdMode::ForceNeon);
        // CLI slot: Off pins scalar, Auto defers to env/detect.
        set_simd_mode(SimdMode::Off);
        assert_eq!(current_dispatch(), Dispatch::Scalar);
        set_simd_mode(SimdMode::Auto);
        assert_eq!(current_dispatch(), dispatch_for(current_mode()));
    }

    #[test]
    fn dot8_scalar_matches_sequential_dot_approximately() {
        let (a, b) = vecs(100, 7);
        let seq: f64 = a.iter().zip(&b).map(|(&x, &w)| x as f64 * w as f64).sum();
        let lane = dot8_scalar(&a, &b);
        assert!((seq - lane).abs() <= 1e-12 * (1.0 + seq.abs()));
    }

    #[test]
    fn dot8_dispatch_matches_scalar_bitwise() {
        let dsp = dispatch_for(SimdMode::Auto);
        for &n in &LENS {
            let (a, b) = vecs(n, 11 + n as u64);
            let want = dot8_scalar(&a, &b);
            let got = dot8(dsp, &a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "dot8 n={n} dsp={dsp:?}");
        }
    }

    #[test]
    fn widen_dispatch_matches_scalar_bitwise() {
        let dsp = dispatch_for(SimdMode::Auto);
        for &n in &LENS {
            let (src, _) = vecs(n, 23 + n as u64);
            for scale in [None, Some(0.75f32), Some(-1.25f32)] {
                let mut want = vec![0.0f64; n];
                let mut got = vec![1.0f64; n];
                widen_scalar(&mut want, &src, scale);
                widen(dsp, &mut got, &src, scale);
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(g.to_bits(), w.to_bits(), "widen n={n} scale={scale:?}");
                }
            }
        }
    }

    #[test]
    fn gram_panel_dispatch_matches_scalar_bitwise() {
        let dsp = dispatch_for(SimdMode::Auto);
        let mut r = Pcg32::new(42);
        for &(da, db) in &[(1usize, 1usize), (3, 5), (4, 4), (7, 9), (8, 8), (5, 17)] {
            let abuf: Vec<f64> = (0..da).map(|_| r.normal_f32() as f64).collect();
            let pbuf: Vec<f64> = (0..db).map(|_| r.normal_f32() as f64).collect();
            let mut want: Vec<f64> = (0..da * db).map(|_| r.normal_f32() as f64).collect();
            let mut got = want.clone();
            gram_panel_scalar(&mut want, &abuf, &pbuf);
            gram_panel_update(dsp, &mut got, &abuf, &pbuf);
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(g.to_bits(), w.to_bits(), "gram_panel {da}x{db}");
            }
        }
    }

    #[test]
    fn axpy_widen_dispatch_matches_scalar_bitwise() {
        let dsp = dispatch_for(SimdMode::Auto);
        for &n in &LENS {
            let (b, accs) = vecs(n, 57 + n as u64);
            let mut want: Vec<f64> = accs.iter().map(|&v| v as f64).collect();
            let mut got = want.clone();
            axpy_widen_scalar(&mut want, 0.625f64, &b);
            axpy_widen(dsp, &mut got, 0.625f64, &b);
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(g.to_bits(), w.to_bits(), "axpy n={n}");
            }
        }
    }
}
