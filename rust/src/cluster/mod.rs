//! Cluster economics: EC2-style instance catalog, cost accounting for
//! fixed clusters, and a target-utilization autoscaler — the "cost
//! optimizations" objective from the paper's introduction (and the
//! Darwin/Ray-Serve autoscaling claim in §4).

pub mod cost;
pub mod autoscaler;

pub use autoscaler::{AutoscalePolicy, AutoscaleReport};
pub use cost::{CostReport, InstanceType, CATALOG};
