//! Cluster economics and elasticity: EC2-style instance catalog, cost
//! accounting for fixed clusters, and target-utilization autoscaling —
//! the "cost optimizations" objective from the paper's introduction
//! (and the Darwin/Ray-Serve autoscaling claim in §4).
//!
//! * [`cost`] — instance catalog + fixed-cluster cost/utilization math.
//! * [`autoscaler`] — one [`AutoscalePolicy`], two consumers: the
//!   offline gantt [`autoscaler::replay`] used by the cost benches, and
//!   the online [`ReplicaAutoscaler`] that drives the serving plane's
//!   replica count from live queue depth.

pub mod cost;
pub mod autoscaler;

pub use autoscaler::{AutoscalePolicy, AutoscaleReport, ReplicaAutoscaler};
pub use cost::{CostReport, InstanceType, CATALOG};
