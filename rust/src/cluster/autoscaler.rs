//! Target-utilization autoscaling, in two forms sharing one policy.
//!
//! [`replay`] is the *offline* form: it replays a gantt (from the
//! simulated executor) and asks what an autoscaled cluster — scale-up
//! when pending work exceeds capacity, scale-down after an idle
//! timeout — would have cost.  This reproduces the paper's §1/§4 "cost
//! optimization via autoscaling" claim as a measurable table
//! (benches/cost_table.rs).
//!
//! [`ReplicaAutoscaler`] is the *online* form: the serving plane's
//! queue-depth controller.  It reuses the same [`AutoscalePolicy`] knobs
//! (`min_nodes`/`max_nodes` bound the replica set, `slots_per_node` is
//! the target backlog per replica, `idle_timeout` delays scale-down) and
//! adds a sustain window so a momentary burst does not thrash the
//! replica count.

use crate::raylet::sim::GanttEntry;

/// Autoscaling policy knobs.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    pub min_nodes: usize,
    pub max_nodes: usize,
    pub slots_per_node: usize,
    /// Seconds a node must sit idle before being released.
    pub idle_timeout: f64,
    /// Seconds to boot a node (EC2: ~minutes; Ray on warm pool: seconds).
    pub boot_time: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 10,
            slots_per_node: 8,
            idle_timeout: 60.0,
            boot_time: 30.0,
        }
    }
}

/// Result of replaying a schedule under the policy.
#[derive(Clone, Debug, Default)]
pub struct AutoscaleReport {
    pub node_hours: f64,
    pub dollars_at: f64,
    pub peak_nodes: usize,
    /// (time, node_count) scale events, starting at (0, min_nodes).
    pub events: Vec<(f64, usize)>,
}

/// Replay `gantt` under the policy at `dollars_per_node_hour`.
///
/// Demand at time t = concurrent tasks; desired nodes =
/// ceil(demand / slots_per_node) clamped to [min, max].  Scale-up pays
/// `boot_time` of lead (approximated as extra billed time), scale-down
/// waits `idle_timeout`.  Node-hours integrate the resulting step
/// function.
pub fn replay(
    gantt: &[GanttEntry],
    policy: &AutoscalePolicy,
    dollars_per_node_hour: f64,
) -> AutoscaleReport {
    if gantt.is_empty() {
        return AutoscaleReport {
            events: vec![(0.0, policy.min_nodes)],
            ..Default::default()
        };
    }
    // demand step function from task start/end events
    let mut edges: Vec<(f64, i64)> = Vec::with_capacity(gantt.len() * 2);
    for g in gantt {
        edges.push((g.start, 1));
        edges.push((g.end, -1));
    }
    edges.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)));
    let horizon = gantt.iter().map(|g| g.end).fold(0.0, f64::max);

    let mut report = AutoscaleReport::default();
    let mut nodes = policy.min_nodes;
    report.events.push((0.0, nodes));
    report.peak_nodes = nodes;

    let mut node_seconds = 0.0;
    let mut t_prev = 0.0;
    let mut demand: i64 = 0;
    // when the cluster became over-provisioned (scale-down armed)
    let mut idle_since: Option<f64> = None;

    let desired_for = |demand: i64| -> usize {
        ((demand.max(0) as usize).div_ceil(policy.slots_per_node))
            .clamp(policy.min_nodes, policy.max_nodes)
    };

    let mut i = 0;
    loop {
        let next_edge = edges.get(i).map(|e| e.0);
        let deadline = idle_since.map(|s| s + policy.idle_timeout);
        // next decision instant: earliest of (edge, scale-down deadline)
        let t = match (next_edge, deadline) {
            (Some(e), Some(d)) => e.min(d),
            (Some(e), None) => e,
            (None, Some(d)) if d <= horizon => d,
            _ => break,
        };
        // integrate current node count over [t_prev, t]
        node_seconds += nodes as f64 * (t - t_prev);
        t_prev = t;

        // scale-down deadline fires first (or simultaneously)
        if deadline.is_some_and(|d| d <= t && next_edge.map_or(true, |e| d <= e)) {
            let desired = desired_for(demand);
            if desired < nodes {
                nodes = desired;
                report.events.push((t, nodes));
            }
            idle_since = None;
            if next_edge != Some(t) {
                continue;
            }
        }

        // apply all edges at time t
        while i < edges.len() && edges[i].0 == t {
            demand += edges[i].1;
            i += 1;
        }
        let desired = desired_for(demand);
        if desired > nodes {
            // scale up: bill the boot lead time for the new nodes
            node_seconds += (desired - nodes) as f64 * policy.boot_time;
            nodes = desired;
            report.events.push((t, nodes));
            idle_since = None;
        } else if desired < nodes {
            if idle_since.is_none() {
                idle_since = Some(t);
            }
        } else {
            idle_since = None;
        }
        report.peak_nodes = report.peak_nodes.max(nodes);
    }
    node_seconds += nodes as f64 * (horizon - t_prev).max(0.0);

    report.node_hours = node_seconds / 3600.0;
    report.dollars_at = report.node_hours * dollars_per_node_hour;
    report
}

/// Online queue-depth autoscaler for the serving plane.
///
/// Feed it `(time, backlog, live replica count)` observations through
/// [`observe`]; it returns `Some(desired)` when the replica set should
/// change size.  Decision rule, reusing the [`AutoscalePolicy`] knobs:
///
/// * desired = `ceil(backlog / slots_per_node)` clamped to
///   `[min_nodes, max_nodes]`;
/// * scale **up** only after desired has exceeded the live count for at
///   least `sustain` seconds (sustained backlog, not a burst);
/// * scale **down** only after desired has been below the live count
///   for at least `policy.idle_timeout` seconds.
///
/// [`observe`]: ReplicaAutoscaler::observe
#[derive(Clone, Debug)]
pub struct ReplicaAutoscaler {
    /// Shared knobs: replica bounds, per-replica backlog target,
    /// scale-down idle timeout.
    pub policy: AutoscalePolicy,
    /// Seconds the backlog must stay over capacity before scaling up.
    pub sustain: f64,
    /// `(time, desired)` scale decisions actually emitted.
    pub events: Vec<(f64, usize)>,
    over_since: Option<f64>,
    idle_since: Option<f64>,
}

impl ReplicaAutoscaler {
    pub fn new(policy: AutoscalePolicy, sustain: f64) -> ReplicaAutoscaler {
        ReplicaAutoscaler {
            policy,
            sustain,
            events: Vec::new(),
            over_since: None,
            idle_since: None,
        }
    }

    /// Observe the serving plane at time `t` (seconds since start) with
    /// `backlog` requests outstanding (queued + in flight) across
    /// `replicas` live replicas.  Returns the new desired replica count
    /// when a scale event fires, `None` otherwise.
    pub fn observe(&mut self, t: f64, backlog: usize, replicas: usize) -> Option<usize> {
        let desired = backlog
            .div_ceil(self.policy.slots_per_node.max(1))
            .clamp(self.policy.min_nodes, self.policy.max_nodes);
        if desired > replicas {
            self.idle_since = None;
            let since = *self.over_since.get_or_insert(t);
            if t - since >= self.sustain {
                self.over_since = None;
                self.events.push((t, desired));
                return Some(desired);
            }
            return None;
        }
        self.over_since = None;
        if desired < replicas {
            let since = *self.idle_since.get_or_insert(t);
            if t - since >= self.policy.idle_timeout {
                self.idle_since = None;
                self.events.push((t, desired));
                return Some(desired);
            }
            return None;
        }
        self.idle_since = None;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bar(node: usize, start: f64, end: f64) -> GanttEntry {
        GanttEntry { label: "t".into(), node, start, end }
    }

    #[test]
    fn empty_gantt() {
        let r = replay(&[], &AutoscalePolicy::default(), 1.0);
        assert_eq!(r.node_hours, 0.0);
        assert_eq!(r.events, vec![(0.0, 1)]);
    }

    #[test]
    fn burst_scales_up_then_down() {
        // 32 concurrent 100s tasks, then one 1000s task
        let mut g: Vec<GanttEntry> = (0..32).map(|i| bar(i % 4, 0.0, 100.0)).collect();
        g.push(bar(0, 100.0, 1100.0));
        let p = AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 8,
            slots_per_node: 8,
            idle_timeout: 50.0,
            boot_time: 0.0,
        };
        let r = replay(&g, &p, 1.0);
        assert_eq!(r.peak_nodes, 4); // 32 tasks / 8 slots
        // scaled back to 1 for the tail
        assert_eq!(*r.events.last().map(|(_, n)| n).unwrap(), 1);
        // node-hours: ~4 nodes * 100s + ~1 node * 1000s  (+ idle_timeout lag at 4)
        let expect_lo = (4.0 * 100.0 + 1000.0) / 3600.0;
        let expect_hi = (4.0 * 200.0 + 1000.0) / 3600.0;
        assert!(r.node_hours >= expect_lo && r.node_hours <= expect_hi, "{}", r.node_hours);
    }

    #[test]
    fn autoscaled_cheaper_than_fixed_for_bursty_load() {
        let mut g: Vec<GanttEntry> = (0..40).map(|i| bar(i % 5, 0.0, 60.0)).collect();
        g.push(bar(0, 60.0, 3660.0)); // 1h serial tail
        let p = AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 5,
            slots_per_node: 8,
            idle_timeout: 30.0,
            boot_time: 0.0,
        };
        let auto = replay(&g, &p, 1.0);
        let fixed = 5.0 * 3660.0 / 3600.0; // 5 nodes whole run
        assert!(auto.dollars_at < fixed * 0.5, "auto={} fixed={fixed}", auto.dollars_at);
    }

    fn serve_policy(min: usize, max: usize, idle: f64) -> AutoscalePolicy {
        AutoscalePolicy {
            min_nodes: min,
            max_nodes: max,
            slots_per_node: 8,
            idle_timeout: idle,
            boot_time: 0.0,
        }
    }

    #[test]
    fn replica_scaler_scales_up_on_sustained_backlog_only() {
        let mut sc = ReplicaAutoscaler::new(serve_policy(1, 4, 10.0), 1.0);
        // burst at t=0: over capacity but not sustained yet
        assert_eq!(sc.observe(0.0, 40, 1), None);
        // still over at t=1.5 => sustained => scale to ceil(40/8)=5 -> 4
        assert_eq!(sc.observe(1.5, 40, 1), Some(4));
        // burst that clears before the sustain window never fires
        let mut sc2 = ReplicaAutoscaler::new(serve_policy(1, 4, 10.0), 1.0);
        assert_eq!(sc2.observe(0.0, 40, 1), None);
        assert_eq!(sc2.observe(0.5, 4, 1), None); // backlog cleared
        assert_eq!(sc2.observe(5.0, 40, 1), None); // window restarts
        assert!(sc2.events.is_empty());
    }

    #[test]
    fn replica_scaler_scales_down_after_idle_timeout() {
        let mut sc = ReplicaAutoscaler::new(serve_policy(1, 4, 2.0), 0.0);
        assert_eq!(sc.observe(0.0, 0, 4), None); // idle starts
        assert_eq!(sc.observe(1.0, 0, 4), None); // not idle long enough
        assert_eq!(sc.observe(2.5, 0, 4), Some(1));
        // zero timeouts fire immediately (the test configuration)
        let mut fast = ReplicaAutoscaler::new(serve_policy(1, 4, 0.0), 0.0);
        assert_eq!(fast.observe(0.0, 100, 1), Some(4));
        assert_eq!(fast.observe(0.0, 0, 4), Some(1));
        assert_eq!(fast.events.len(), 2);
    }

    #[test]
    fn replica_scaler_holds_steady_in_band() {
        let mut sc = ReplicaAutoscaler::new(serve_policy(1, 4, 0.0), 0.0);
        // backlog of 9..16 on 2 replicas => desired 2 => no event, ever
        for t in 0..10 {
            assert_eq!(sc.observe(t as f64, 9 + t % 8, 2), None);
        }
        assert!(sc.events.is_empty());
    }

    #[test]
    fn boot_time_billed() {
        let g = vec![bar(0, 0.0, 10.0); 80];
        let p = AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 10,
            slots_per_node: 8,
            idle_timeout: 1e9,
            boot_time: 3600.0,
        };
        let r = replay(&g, &p, 1.0);
        // 9 extra nodes * 1h boot = 9 node-hours minimum
        assert!(r.node_hours > 9.0, "{}", r.node_hours);
    }
}
