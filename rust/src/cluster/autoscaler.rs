//! Target-utilization autoscaler over a recorded schedule.
//!
//! Replays a gantt (from the simulated executor) and asks: if the
//! cluster had scaled node count to demand — scale-up when pending work
//! exceeds capacity, scale-down after an idle timeout — what would the
//! run have cost?  This reproduces the paper's §1/§4 "cost optimization
//! via autoscaling" claim as a measurable table (benches/cost_table.rs).

use crate::raylet::sim::GanttEntry;

/// Autoscaling policy knobs.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    pub min_nodes: usize,
    pub max_nodes: usize,
    pub slots_per_node: usize,
    /// Seconds a node must sit idle before being released.
    pub idle_timeout: f64,
    /// Seconds to boot a node (EC2: ~minutes; Ray on warm pool: seconds).
    pub boot_time: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 10,
            slots_per_node: 8,
            idle_timeout: 60.0,
            boot_time: 30.0,
        }
    }
}

/// Result of replaying a schedule under the policy.
#[derive(Clone, Debug, Default)]
pub struct AutoscaleReport {
    pub node_hours: f64,
    pub dollars_at: f64,
    pub peak_nodes: usize,
    /// (time, node_count) scale events, starting at (0, min_nodes).
    pub events: Vec<(f64, usize)>,
}

/// Replay `gantt` under the policy at `dollars_per_node_hour`.
///
/// Demand at time t = concurrent tasks; desired nodes =
/// ceil(demand / slots_per_node) clamped to [min, max].  Scale-up pays
/// `boot_time` of lead (approximated as extra billed time), scale-down
/// waits `idle_timeout`.  Node-hours integrate the resulting step
/// function.
pub fn replay(
    gantt: &[GanttEntry],
    policy: &AutoscalePolicy,
    dollars_per_node_hour: f64,
) -> AutoscaleReport {
    if gantt.is_empty() {
        return AutoscaleReport {
            events: vec![(0.0, policy.min_nodes)],
            ..Default::default()
        };
    }
    // demand step function from task start/end events
    let mut edges: Vec<(f64, i64)> = Vec::with_capacity(gantt.len() * 2);
    for g in gantt {
        edges.push((g.start, 1));
        edges.push((g.end, -1));
    }
    edges.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)));
    let horizon = gantt.iter().map(|g| g.end).fold(0.0, f64::max);

    let mut report = AutoscaleReport::default();
    let mut nodes = policy.min_nodes;
    report.events.push((0.0, nodes));
    report.peak_nodes = nodes;

    let mut node_seconds = 0.0;
    let mut t_prev = 0.0;
    let mut demand: i64 = 0;
    // when the cluster became over-provisioned (scale-down armed)
    let mut idle_since: Option<f64> = None;

    let desired_for = |demand: i64| -> usize {
        ((demand.max(0) as usize).div_ceil(policy.slots_per_node))
            .clamp(policy.min_nodes, policy.max_nodes)
    };

    let mut i = 0;
    loop {
        let next_edge = edges.get(i).map(|e| e.0);
        let deadline = idle_since.map(|s| s + policy.idle_timeout);
        // next decision instant: earliest of (edge, scale-down deadline)
        let t = match (next_edge, deadline) {
            (Some(e), Some(d)) => e.min(d),
            (Some(e), None) => e,
            (None, Some(d)) if d <= horizon => d,
            _ => break,
        };
        // integrate current node count over [t_prev, t]
        node_seconds += nodes as f64 * (t - t_prev);
        t_prev = t;

        // scale-down deadline fires first (or simultaneously)
        if deadline.is_some_and(|d| d <= t && next_edge.map_or(true, |e| d <= e)) {
            let desired = desired_for(demand);
            if desired < nodes {
                nodes = desired;
                report.events.push((t, nodes));
            }
            idle_since = None;
            if next_edge != Some(t) {
                continue;
            }
        }

        // apply all edges at time t
        while i < edges.len() && edges[i].0 == t {
            demand += edges[i].1;
            i += 1;
        }
        let desired = desired_for(demand);
        if desired > nodes {
            // scale up: bill the boot lead time for the new nodes
            node_seconds += (desired - nodes) as f64 * policy.boot_time;
            nodes = desired;
            report.events.push((t, nodes));
            idle_since = None;
        } else if desired < nodes {
            if idle_since.is_none() {
                idle_since = Some(t);
            }
        } else {
            idle_since = None;
        }
        report.peak_nodes = report.peak_nodes.max(nodes);
    }
    node_seconds += nodes as f64 * (horizon - t_prev).max(0.0);

    report.node_hours = node_seconds / 3600.0;
    report.dollars_at = report.node_hours * dollars_per_node_hour;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bar(node: usize, start: f64, end: f64) -> GanttEntry {
        GanttEntry { label: "t".into(), node, start, end }
    }

    #[test]
    fn empty_gantt() {
        let r = replay(&[], &AutoscalePolicy::default(), 1.0);
        assert_eq!(r.node_hours, 0.0);
        assert_eq!(r.events, vec![(0.0, 1)]);
    }

    #[test]
    fn burst_scales_up_then_down() {
        // 32 concurrent 100s tasks, then one 1000s task
        let mut g: Vec<GanttEntry> = (0..32).map(|i| bar(i % 4, 0.0, 100.0)).collect();
        g.push(bar(0, 100.0, 1100.0));
        let p = AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 8,
            slots_per_node: 8,
            idle_timeout: 50.0,
            boot_time: 0.0,
        };
        let r = replay(&g, &p, 1.0);
        assert_eq!(r.peak_nodes, 4); // 32 tasks / 8 slots
        // scaled back to 1 for the tail
        assert_eq!(*r.events.last().map(|(_, n)| n).unwrap(), 1);
        // node-hours: ~4 nodes * 100s + ~1 node * 1000s  (+ idle_timeout lag at 4)
        let expect_lo = (4.0 * 100.0 + 1000.0) / 3600.0;
        let expect_hi = (4.0 * 200.0 + 1000.0) / 3600.0;
        assert!(r.node_hours >= expect_lo && r.node_hours <= expect_hi, "{}", r.node_hours);
    }

    #[test]
    fn autoscaled_cheaper_than_fixed_for_bursty_load() {
        let mut g: Vec<GanttEntry> = (0..40).map(|i| bar(i % 5, 0.0, 60.0)).collect();
        g.push(bar(0, 60.0, 3660.0)); // 1h serial tail
        let p = AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 5,
            slots_per_node: 8,
            idle_timeout: 30.0,
            boot_time: 0.0,
        };
        let auto = replay(&g, &p, 1.0);
        let fixed = 5.0 * 3660.0 / 3600.0; // 5 nodes whole run
        assert!(auto.dollars_at < fixed * 0.5, "auto={} fixed={fixed}", auto.dollars_at);
    }

    #[test]
    fn boot_time_billed() {
        let g = vec![bar(0, 0.0, 10.0); 80];
        let p = AutoscalePolicy {
            min_nodes: 1,
            max_nodes: 10,
            slots_per_node: 8,
            idle_timeout: 1e9,
            boot_time: 3600.0,
        };
        let r = replay(&g, &p, 1.0);
        // 9 extra nodes * 1h boot = 9 node-hours minimum
        assert!(r.node_hours > 9.0, "{}", r.node_hours);
    }
}
