//! EC2-style cost accounting.
//!
//! The paper runs on "EC2 high-memory" nodes; per-node-hour prices here
//! are the public on-demand us-east-1 list prices (mid-2023) for the
//! family the paper plausibly used.  Absolute dollars are illustrative;
//! the *ratios* (sequential single node vs 5-node cluster vs autoscaled)
//! are the reproducible content.

/// One rentable node type.
#[derive(Clone, Copy, Debug)]
pub struct InstanceType {
    pub name: &'static str,
    pub vcpus: usize,
    pub mem_gb: usize,
    pub dollars_per_hour: f64,
}

/// A small on-demand catalog (us-east-1, mid-2023 list).
pub const CATALOG: &[InstanceType] = &[
    InstanceType { name: "r5.xlarge", vcpus: 4, mem_gb: 32, dollars_per_hour: 0.252 },
    InstanceType { name: "r5.2xlarge", vcpus: 8, mem_gb: 64, dollars_per_hour: 0.504 },
    InstanceType { name: "r5.4xlarge", vcpus: 16, mem_gb: 128, dollars_per_hour: 1.008 },
    InstanceType { name: "r5.8xlarge", vcpus: 32, mem_gb: 256, dollars_per_hour: 2.016 },
];

/// Look up an instance type by name in [`CATALOG`].
pub fn instance(name: &str) -> Option<&'static InstanceType> {
    CATALOG.iter().find(|i| i.name == name)
}

/// Cost summary of one run.
#[derive(Clone, Debug, Default)]
pub struct CostReport {
    pub node_hours: f64,
    pub dollars: f64,
    /// Mean fraction of slot-seconds actually busy.
    pub utilization: f64,
}

/// Fixed-size cluster held for the whole schedule.
pub fn fixed_cluster_cost(
    makespan_secs: f64,
    nodes: usize,
    dollars_per_node_hour: f64,
    busy_secs: f64,
    slots_per_node: usize,
) -> CostReport {
    let node_hours = nodes as f64 * makespan_secs / 3600.0;
    let capacity = makespan_secs * (nodes * slots_per_node) as f64;
    CostReport {
        node_hours,
        dollars: node_hours * dollars_per_node_hour,
        utilization: if capacity > 0.0 { (busy_secs / capacity).min(1.0) } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        assert_eq!(instance("r5.4xlarge").unwrap().vcpus, 16);
        assert!(instance("nope").is_none());
    }

    #[test]
    fn fixed_cost_math() {
        // 5 nodes for 30 min at $1.008 => 2.5 node-hours => $2.52
        let r = fixed_cluster_cost(1800.0, 5, 1.008, 1800.0 * 20.0, 8);
        assert!((r.node_hours - 2.5).abs() < 1e-9);
        assert!((r.dollars - 2.52).abs() < 1e-9);
        assert!((r.utilization - 0.5).abs() < 1e-9); // 20 busy of 40 slots
    }

    #[test]
    fn utilization_clamped() {
        let r = fixed_cluster_cost(10.0, 1, 1.0, 1e9, 1);
        assert!(r.utilization <= 1.0);
    }
}
