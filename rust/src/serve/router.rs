//! Request router: replica selection + batched CATE prediction.
//!
//! A [`CateModel`] is the deployable artifact of a DML fit (theta + the
//! het-feature layout).  The [`Router`] drives the batcher, executes
//! padded predict blocks through the backend, and keeps latency stats.

use std::time::Instant;

use crate::data::matrix::Matrix;
use crate::error::{NexusError, Result};
use crate::runtime::backend::KernelExec;
use crate::serve::batcher::{BatchPolicy, Batcher, Request};
use crate::util::timer::Stats;

/// Deployable CATE head: tau(x) = theta[0] + sum_j theta[j+1] x_j.
#[derive(Clone, Debug)]
pub struct CateModel {
    pub theta: Vec<f32>,
    pub het: usize,
    /// Block size for padded batch prediction (a shipped artifact size
    /// under PJRT; any size under host).
    pub block: usize,
    /// Padded feature width for the predict artifact.
    pub d_pad: usize,
}

impl CateModel {
    pub fn from_dml(fit: &crate::causal::dml::DmlFit, block: usize, d_pad: usize) -> CateModel {
        CateModel { theta: fit.theta.clone(), het: fit.het, block, d_pad }
    }

    /// Coefficient vector padded to d_pad: [theta0, theta_het..., 0...].
    fn beta_padded(&self) -> Vec<f32> {
        let mut beta = self.theta.clone();
        beta.resize(self.d_pad, 0.0);
        beta
    }
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub queue_wait: Stats,
    pub exec_time: Stats,
}

impl ServeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Single-replica router (replica = one backend executor; the simulated
/// cluster layer handles multi-node placement for batch scoring jobs).
pub struct Router<'a> {
    pub model: CateModel,
    pub kx: &'a dyn KernelExec,
    batcher: Batcher,
    stats: ServeStats,
    next_id: u64,
    /// Completed responses (id, cate).
    pub completed: Vec<(u64, f32)>,
}

impl<'a> Router<'a> {
    pub fn new(model: CateModel, kx: &'a dyn KernelExec, policy: BatchPolicy) -> Router<'a> {
        Router { model, kx, batcher: Batcher::new(policy), stats: ServeStats::default(), next_id: 0, completed: Vec::new() }
    }

    /// Enqueue one request; returns its id.
    pub fn enqueue(&mut self, het_features: Vec<f32>) -> Result<u64> {
        if het_features.len() < self.model.het {
            return Err(NexusError::Serve(format!(
                "need {} het features, got {}",
                self.model.het,
                het_features.len()
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request { id, features: het_features, enqueued: Instant::now() });
        self.tick(false)?;
        Ok(id)
    }

    /// Drive the batcher: flush when policy says so (or `force`).
    pub fn tick(&mut self, force: bool) -> Result<()> {
        let now = Instant::now();
        while self.batcher.should_flush(now) || (force && !self.batcher.is_empty()) {
            let batch = self.batcher.take_batch();
            self.execute(batch)?;
        }
        Ok(())
    }

    /// Flush everything (end of stream).
    pub fn flush(&mut self) -> Result<()> {
        self.tick(true)
    }

    fn execute(&mut self, batch: Vec<Request>) -> Result<()> {
        let now = Instant::now();
        let b = self.model.block;
        let d = self.model.d_pad;
        // pad the batch into a [block, d_pad] design: col 0 = 1 (intercept)
        let mut x = Matrix::zeros(b, d);
        for (r, req) in batch.iter().enumerate() {
            if r >= b {
                return Err(NexusError::Serve("batch exceeds block".into()));
            }
            x.set(r, 0, 1.0);
            for j in 0..self.model.het {
                x.set(r, j + 1, req.features[j]);
            }
        }
        let exec_start = Instant::now();
        let pred = self.kx.predict(&x, &self.model.beta_padded())?;
        self.stats.exec_time.record(exec_start.elapsed());
        for (r, req) in batch.iter().enumerate() {
            self.stats.queue_wait.record(now.duration_since(req.enqueued));
            self.completed.push((req.id, pred[r]));
        }
        self.stats.requests += batch.len() as u64;
        self.stats.batches += 1;
        Ok(())
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use std::time::Duration;

    fn model() -> CateModel {
        CateModel { theta: vec![1.0, 0.5], het: 1, block: 8, d_pad: 4 }
    }

    #[test]
    fn single_request_roundtrip() {
        let kx = HostBackend;
        let mut r = Router::new(
            model(),
            &kx,
            BatchPolicy { max_batch: 1, max_delay: Duration::ZERO },
        );
        let id = r.enqueue(vec![2.0]).unwrap();
        r.flush().unwrap();
        let (rid, cate) = r.completed[0];
        assert_eq!(rid, id);
        assert!((cate - 2.0).abs() < 1e-6); // 1 + 0.5*2
    }

    #[test]
    fn batching_coalesces() {
        let kx = HostBackend;
        let mut r = Router::new(
            model(),
            &kx,
            BatchPolicy { max_batch: 4, max_delay: Duration::from_secs(100) },
        );
        for i in 0..8 {
            r.enqueue(vec![i as f32]).unwrap();
        }
        r.flush().unwrap();
        let s = r.stats();
        assert_eq!(s.requests, 8);
        assert_eq!(s.batches, 2, "4+4");
        assert_eq!(s.mean_batch_size(), 4.0);
        // answers are correct per request
        for (id, cate) in &r.completed {
            assert!((cate - (1.0 + 0.5 * *id as f32)).abs() < 1e-5);
        }
    }

    #[test]
    fn rejects_short_features() {
        let kx = HostBackend;
        let mut r = Router::new(model(), &kx, BatchPolicy::default());
        assert!(r.enqueue(vec![]).is_err());
    }
}
