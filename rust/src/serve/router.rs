//! Multi-replica request router: replica placement, pluggable routing
//! policies, failover, and latency accounting.
//!
//! A [`CateModel`] is the deployable artifact of a DML fit (theta + the
//! het-feature layout).  The [`Router`] is the serving front-end: it
//! owns N replica actors (see [`crate::serve::replica`]), keeps one
//! dynamic [`Batcher`] per replica, routes each incoming request to a
//! replica under a [`RoutingPolicy`], dispatches flushed batches as
//! asynchronous actor calls, and collects results without blocking the
//! request path.  Per-request end-to-end latency (p50/p95/p99), queue
//! wait, and batch execution time accumulate in [`ServeStats`].
//!
//! Failover: if a replica dies mid-stream ([`Router::kill_replica`], or
//! an actor call erroring out), its queued and in-flight requests are
//! re-routed to surviving replicas — no request is lost as long as one
//! replica remains (`tests/serve_failover.rs`).
//!
//! Elasticity: attach a [`ReplicaAutoscaler`] with
//! [`Router::with_autoscaler`] and the router grows the replica set on
//! sustained backlog and retires replicas after an idle timeout.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::autoscaler::ReplicaAutoscaler;
use crate::data::matrix::Matrix;
use crate::error::{NexusError, Result};
use crate::raylet::actor::{self, ActorHandle, CallRef};
use crate::raylet::payload::Payload;
use crate::runtime::backend::KernelExec;
use crate::runtime::tensor::Tensor;
use crate::serve::batcher::{BatchPolicy, Batcher, Request};
use crate::serve::replica::ReplicaActor;
use crate::util::rng::Pcg32;
use crate::util::timer::Stats;

/// Deployable CATE head: tau(x) = theta[0] + sum_j theta[j+1] x_j.
#[derive(Clone, Debug)]
pub struct CateModel {
    /// Final-stage coefficients: intercept followed by `het` slopes.
    pub theta: Vec<f32>,
    /// Heterogeneous-effect features each request must carry.
    pub het: usize,
    /// Block size for padded batch prediction (a shipped artifact size
    /// under PJRT; any size under host).
    pub block: usize,
    /// Padded feature width for the predict artifact.
    pub d_pad: usize,
}

impl CateModel {
    /// Package a DML fit as a servable model.
    pub fn from_dml(fit: &crate::causal::dml::DmlFit, block: usize, d_pad: usize) -> CateModel {
        CateModel { theta: fit.theta.clone(), het: fit.het, block, d_pad }
    }

    /// Coefficient vector padded to d_pad: [theta0, theta_het..., 0...].
    fn beta_padded(&self) -> Vec<f32> {
        let mut beta = self.theta.clone();
        beta.resize(self.d_pad, 0.0);
        beta
    }

    /// Is this model's shape servable at all?
    pub fn validate(&self) -> Result<()> {
        if self.block == 0 {
            return Err(NexusError::Serve("model block size must be positive".into()));
        }
        if self.het + 1 > self.d_pad {
            return Err(NexusError::Serve(format!(
                "model needs {} design columns but d_pad is only {}",
                self.het + 1,
                self.d_pad
            )));
        }
        Ok(())
    }

    /// Predict one batch of `k` requests whose het features are packed
    /// row-major in `flat` (`k * het` values).  Pads the batch into a
    /// `[block, d_pad]` design (col 0 = intercept) and truncates the
    /// kernel output back to `k`.  This is the compute every replica
    /// actor runs per mailbox message.
    pub fn predict_block(&self, kx: &dyn KernelExec, flat: &[f32], k: usize) -> Result<Vec<f32>> {
        self.validate()?;
        if k > self.block {
            return Err(NexusError::Serve(format!(
                "batch of {k} exceeds model block {}",
                self.block
            )));
        }
        if flat.len() != k * self.het {
            return Err(NexusError::Serve(format!(
                "expected {} packed features for {k} requests, got {}",
                k * self.het,
                flat.len()
            )));
        }
        let mut x = Matrix::zeros(self.block, self.d_pad);
        for r in 0..k {
            x.set(r, 0, 1.0);
            for j in 0..self.het {
                x.set(r, j + 1, flat[r * self.het + j]);
            }
        }
        let pred = kx.predict(&x, &self.beta_padded())?;
        Ok(pred[..k].to_vec())
    }
}

/// How the router spreads requests over live replicas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through live replicas in order — fair under uniform cost.
    RoundRobin,
    /// Send to the replica with the fewest outstanding requests
    /// (queued + in flight) — best tail latency, O(replicas) per pick.
    LeastOutstanding,
    /// Power-of-two-choices: sample two distinct replicas, pick the
    /// less-loaded — near-LOR balance at O(1) cost (Mitzenmacher).
    PowerOfTwo,
}

impl RoutingPolicy {
    /// Parse a CLI name: `rr`, `lor`, `p2c` (plus long spellings).
    pub fn parse(s: &str) -> Result<RoutingPolicy> {
        match s {
            "rr" | "round-robin" => Ok(RoutingPolicy::RoundRobin),
            "lor" | "least" | "least-outstanding" => Ok(RoutingPolicy::LeastOutstanding),
            "p2c" | "power-of-two" => Ok(RoutingPolicy::PowerOfTwo),
            other => Err(NexusError::Config(format!("unknown routing policy '{other}'"))),
        }
    }

    /// Canonical short name (for reports).
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::LeastOutstanding => "lor",
            RoutingPolicy::PowerOfTwo => "p2c",
        }
    }
}

/// Serving statistics, accumulated by the router.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests re-routed after a replica died or a call failed.
    pub rerouted: u64,
    /// Enqueue -> dispatch wait per request.
    pub queue_wait: Stats,
    /// Dispatch -> completion time per batch (mailbox wait + kernel).
    pub exec_time: Stats,
    /// Enqueue -> completion end-to-end latency per request; report
    /// `latency.p50() / .p95() / .p99()`.
    pub latency: Stats,
}

impl ServeStats {
    /// Mean requests per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// One batch in flight to a replica actor.
struct PendingBatch {
    call: CallRef,
    reqs: Vec<Request>,
    dispatched: Instant,
}

/// One replica: the actor handle, its private batcher, and its in-flight
/// window.
struct Replica {
    handle: ActorHandle,
    batcher: Batcher,
    pending: VecDeque<PendingBatch>,
    alive: bool,
    /// Requests ever dispatched to this replica (for load reports).
    dispatched_reqs: u64,
}

impl Replica {
    /// Outstanding load: queued + in flight, in requests.
    fn depth(&self) -> usize {
        self.batcher.len() + self.pending.iter().map(|b| b.reqs.len()).sum::<usize>()
    }
}

/// Multi-replica serving front-end.  See the module docs for the data
/// flow; the public surface is [`enqueue`] / [`tick`] / [`drain`] plus
/// [`kill_replica`] for failover testing.
///
/// [`enqueue`]: Router::enqueue
/// [`tick`]: Router::tick
/// [`drain`]: Router::drain
/// [`kill_replica`]: Router::kill_replica
pub struct Router {
    /// The deployed model (every replica serves a clone of it).
    pub model: CateModel,
    kx: Arc<dyn KernelExec>,
    batch_policy: BatchPolicy,
    routing: RoutingPolicy,
    replicas: Vec<Replica>,
    rr_next: usize,
    rng: Pcg32,
    stats: ServeStats,
    next_id: u64,
    next_replica_id: usize,
    autoscaler: Option<ReplicaAutoscaler>,
    started: Instant,
    /// Completed responses (request id, cate).
    pub completed: Vec<(u64, f32)>,
}

impl Router {
    /// Deploy `model` as `replicas` actor-backed replicas.
    ///
    /// Configuration is validated HERE, not at first flush: a
    /// `BatchPolicy::max_batch` larger than the model's block would
    /// otherwise surface as a runtime "batch exceeds block" error
    /// mid-stream.
    pub fn new(
        model: CateModel,
        kx: Arc<dyn KernelExec>,
        policy: BatchPolicy,
        routing: RoutingPolicy,
        replicas: usize,
    ) -> Result<Router> {
        model.validate()?;
        if policy.max_batch == 0 {
            return Err(NexusError::Config("batch policy: max_batch must be positive".into()));
        }
        if policy.max_batch > model.block {
            return Err(NexusError::Config(format!(
                "batch policy max_batch={} exceeds model block={}; batches could never execute",
                policy.max_batch, model.block
            )));
        }
        if replicas == 0 {
            return Err(NexusError::Config("router needs at least one replica".into()));
        }
        let mut router = Router {
            model,
            kx,
            batch_policy: policy,
            routing,
            replicas: Vec::new(),
            rr_next: 0,
            rng: Pcg32::new(0x5e7e),
            stats: ServeStats::default(),
            next_id: 0,
            next_replica_id: 0,
            autoscaler: None,
            started: Instant::now(),
            completed: Vec::new(),
        };
        for _ in 0..replicas {
            router.spawn_replica();
        }
        Ok(router)
    }

    /// Attach a queue-depth autoscaler; [`Router::tick`] will then grow
    /// the replica set on sustained backlog and retire replicas after
    /// the policy's idle timeout.
    pub fn with_autoscaler(mut self, scaler: ReplicaAutoscaler) -> Router {
        self.autoscaler = Some(scaler);
        self
    }

    /// The attached autoscaler, if any (its `events` record scale
    /// decisions).
    pub fn autoscaler(&self) -> Option<&ReplicaAutoscaler> {
        self.autoscaler.as_ref()
    }

    /// Live replica count.
    pub fn alive_replicas(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).count()
    }

    /// Per-replica load report: (actor name, requests dispatched, alive).
    pub fn replica_loads(&self) -> Vec<(String, u64, bool)> {
        self.replicas
            .iter()
            .map(|r| (r.handle.name.clone(), r.dispatched_reqs, r.alive))
            .collect()
    }

    /// Requests outstanding across all replicas (queued + in flight).
    pub fn backlog(&self) -> usize {
        self.replicas.iter().filter(|r| r.alive).map(|r| r.depth()).sum()
    }

    fn spawn_replica(&mut self) {
        let id = self.next_replica_id;
        self.next_replica_id += 1;
        let handle = actor::spawn(
            &format!("replica-{id}"),
            ReplicaActor::new(self.model.clone(), self.kx.clone()),
        );
        let fresh = Replica {
            handle,
            batcher: Batcher::new(self.batch_policy),
            pending: VecDeque::new(),
            alive: true,
            dispatched_reqs: 0,
        };
        // reuse a fully drained dead slot so autoscale oscillation does
        // not grow the replica vec (and every scan over it) without bound
        let slot = self
            .replicas
            .iter()
            .position(|r| !r.alive && r.pending.is_empty() && r.batcher.is_empty());
        match slot {
            Some(i) => self.replicas[i] = fresh,
            None => self.replicas.push(fresh),
        }
    }

    /// Index of the `k`-th live replica (`k` < live count).
    fn nth_alive(&self, k: usize) -> usize {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.alive)
            .nth(k)
            .map(|(i, _)| i)
            .expect("k < live count")
    }

    /// Pick a live replica index under the routing policy.
    fn pick_replica(&mut self) -> Result<usize> {
        let alive = self.alive_replicas();
        if alive == 0 {
            return Err(NexusError::Serve("no live replicas".into()));
        }
        let idx = match self.routing {
            RoutingPolicy::RoundRobin => {
                let k = self.rr_next % alive;
                self.rr_next = self.rr_next.wrapping_add(1);
                self.nth_alive(k)
            }
            RoutingPolicy::LeastOutstanding => self
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.alive)
                .min_by_key(|(_, r)| r.depth())
                .map(|(i, _)| i)
                .expect("alive > 0"),
            RoutingPolicy::PowerOfTwo => {
                if alive == 1 {
                    self.nth_alive(0)
                } else {
                    let ka = self.rng.below(alive as u64) as usize;
                    let kb = loop {
                        let kb = self.rng.below(alive as u64) as usize;
                        if kb != ka {
                            break kb;
                        }
                    };
                    let a = self.nth_alive(ka);
                    let b = self.nth_alive(kb);
                    if self.replicas[a].depth() <= self.replicas[b].depth() {
                        a
                    } else {
                        b
                    }
                }
            }
        };
        Ok(idx)
    }

    /// Enqueue one request; returns its id.  Routes to a replica's
    /// batcher and drives a non-blocking [`Router::tick`].
    pub fn enqueue(&mut self, het_features: Vec<f32>) -> Result<u64> {
        if het_features.len() < self.model.het {
            return Err(NexusError::Serve(format!(
                "need {} het features, got {}",
                self.model.het,
                het_features.len()
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let i = self.pick_replica()?;
        self.replicas[i].batcher.push(Request {
            id,
            features: het_features,
            enqueued: Instant::now(),
        });
        self.tick()?;
        Ok(id)
    }

    /// Drive the plane without blocking: flush every batcher whose
    /// policy says so, collect any finished batches, feed the
    /// autoscaler.  Call this while idling between arrivals so
    /// delay-triggered flushes happen on time.
    pub fn tick(&mut self) -> Result<()> {
        let now = Instant::now();
        for i in 0..self.replicas.len() {
            while self.replicas[i].alive && self.replicas[i].batcher.should_flush(now) {
                self.dispatch(i);
            }
        }
        self.collect()?;
        self.maybe_scale()
    }

    /// Send one batch from replica `i`'s batcher to its actor.
    fn dispatch(&mut self, i: usize) {
        let batch = self.replicas[i].batcher.take_batch();
        if batch.is_empty() {
            return;
        }
        let k = batch.len();
        let het = self.model.het;
        let mut flat = Vec::with_capacity(k * het);
        for req in &batch {
            flat.extend_from_slice(&req.features[..het]);
        }
        let call = self.replicas[i]
            .handle
            .call("predict", Payload::Tensor(Tensor { shape: vec![k, het], data: flat }));
        self.replicas[i].dispatched_reqs += k as u64;
        self.replicas[i].pending.push_back(PendingBatch {
            call,
            reqs: batch,
            dispatched: Instant::now(),
        });
    }

    /// Record one finished batch into stats + completed.  Validates the
    /// payload BEFORE recording anything: on error nothing is counted
    /// and the caller re-routes the batch's requests (zero loss even
    /// against a misbehaving replica).
    fn complete_batch(&mut self, batch: &PendingBatch, preds: &Payload) -> Result<()> {
        let now = Instant::now();
        let vals = preds.as_floats()?;
        if vals.len() < batch.reqs.len() {
            return Err(NexusError::Serve(format!(
                "replica returned {} predictions for {} requests",
                vals.len(),
                batch.reqs.len()
            )));
        }
        self.stats.exec_time.record(now.duration_since(batch.dispatched));
        for (r, req) in batch.reqs.iter().enumerate() {
            self.stats.queue_wait.record(batch.dispatched.duration_since(req.enqueued));
            self.stats.latency.record(now.duration_since(req.enqueued));
            self.completed.push((req.id, vals[r]));
        }
        self.stats.requests += batch.reqs.len() as u64;
        self.stats.batches += 1;
        Ok(())
    }

    /// Settle one popped batch given its call outcome — the ONE home of
    /// the failover bookkeeping, shared by [`collect`] and [`drain`].
    /// On success the batch is recorded; on a malformed reply the
    /// requests are reclaimed into `reroute` and the protocol error is
    /// captured in `first_err`; on a call error the replica is taken
    /// out of rotation (its retries are exhausted or its actor died —
    /// leaving it live would let re-routes loop back to a persistently
    /// failing replica forever) and the requests are reclaimed.
    ///
    /// [`collect`]: Router::collect
    /// [`drain`]: Router::drain
    fn settle_batch(
        &mut self,
        i: usize,
        batch: PendingBatch,
        got: Result<Payload>,
        reroute: &mut Vec<Request>,
        first_err: &mut Option<NexusError>,
    ) {
        match got {
            Ok(p) => {
                if let Err(e) = self.complete_batch(&batch, &p) {
                    self.stats.rerouted += batch.reqs.len() as u64;
                    reroute.extend(batch.reqs);
                    if first_err.is_none() {
                        *first_err = Some(e);
                    }
                }
            }
            Err(_) => {
                self.replicas[i].alive = false;
                self.stats.rerouted += batch.reqs.len() as u64;
                reroute.extend(batch.reqs);
            }
        }
    }

    /// Non-blocking collection: pop every batch whose result is ready;
    /// re-route the requests of failed batches to surviving replicas.
    /// All reclaimed requests are re-queued BEFORE any error propagates,
    /// so a malformed reply never strands other batches' requests.
    fn collect(&mut self) -> Result<()> {
        let mut reroute: Vec<Request> = Vec::new();
        let mut first_err: Option<NexusError> = None;
        for i in 0..self.replicas.len() {
            loop {
                let call = match self.replicas[i].pending.front() {
                    Some(b) => b.call,
                    None => break,
                };
                let got = match self.replicas[i].handle.try_get(&call) {
                    Some(got) => got,
                    None => {
                        // a killed replica never produces its queued
                        // results; reclaim them instead of waiting
                        if self.replicas[i].handle.is_stopped() {
                            let batch = self.replicas[i].pending.pop_front().expect("front");
                            self.replicas[i].alive = false;
                            self.stats.rerouted += batch.reqs.len() as u64;
                            reroute.extend(batch.reqs);
                            continue;
                        }
                        break;
                    }
                };
                let batch = self.replicas[i].pending.pop_front().expect("front exists");
                self.settle_batch(i, batch, got, &mut reroute, &mut first_err);
            }
            // a retiring replica whose in-flight window has drained can
            // stop now (its mailbox is empty, so the join is immediate)
            if !self.replicas[i].alive
                && self.replicas[i].pending.is_empty()
                && !self.replicas[i].handle.is_stopped()
            {
                self.replicas[i].handle.stop();
            }
        }
        for r in reroute {
            let j = self.pick_replica()?;
            self.replicas[j].batcher.push(r);
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Grow/shrink the replica set per the attached autoscaler.
    fn maybe_scale(&mut self) -> Result<()> {
        if self.autoscaler.is_none() {
            return Ok(());
        }
        let backlog = self.backlog();
        let alive = self.alive_replicas();
        let t = self.started.elapsed().as_secs_f64();
        let desired = match self.autoscaler.as_mut() {
            Some(sc) => sc.observe(t, backlog, alive),
            None => None,
        };
        let Some(desired) = desired else { return Ok(()) };
        while self.alive_replicas() < desired {
            self.spawn_replica();
        }
        while self.alive_replicas() > desired.max(1) {
            self.retire_replica();
        }
        Ok(())
    }

    /// Begin retiring one replica WITHOUT blocking: stop routing to it
    /// and flush its queue as async dispatches; [`Router::tick`]'s
    /// collect pass gathers the in-flight results and stops the actor
    /// once its window drains.  The request path never stalls on a
    /// scale-down decision.
    fn retire_replica(&mut self) {
        let Some(i) = self.replicas.iter().rposition(|r| r.alive) else {
            return;
        };
        while !self.replicas[i].batcher.is_empty() {
            self.dispatch(i);
        }
        self.replicas[i].alive = false;
    }

    /// Simulate a replica crash: kill replica `i`'s actor without
    /// draining, then re-route everything it had queued or in flight.
    /// Results the actor finished before dying are still collected —
    /// nothing is lost and nothing is served twice.
    pub fn kill_replica(&mut self, i: usize) -> Result<()> {
        if i >= self.replicas.len() || !self.replicas[i].alive {
            return Err(NexusError::Serve(format!("no live replica {i}")));
        }
        self.replicas[i].alive = false;
        self.replicas[i].handle.kill();
        let mut reroute: Vec<Request> = Vec::new();
        while let Some(batch) = self.replicas[i].pending.pop_front() {
            let done = match self.replicas[i].handle.try_get(&batch.call) {
                Some(Ok(p)) => self.complete_batch(&batch, &p).is_ok(),
                _ => false,
            };
            if !done {
                self.stats.rerouted += batch.reqs.len() as u64;
                reroute.extend(batch.reqs);
            }
        }
        while !self.replicas[i].batcher.is_empty() {
            let mut left = self.replicas[i].batcher.take_batch();
            self.stats.rerouted += left.len() as u64;
            reroute.append(&mut left);
        }
        for r in reroute {
            let j = self.pick_replica()?;
            self.replicas[j].batcher.push(r);
        }
        self.tick()
    }

    /// Flush everything and block until every request has completed
    /// (end of stream).  Crashed batches re-route until they land on a
    /// live replica; "no live replicas" or a malformed reply surface as
    /// errors — but only after every reclaimed request has been
    /// re-queued, so nothing is stranded.
    pub fn drain(&mut self) -> Result<()> {
        loop {
            let mut progressed = false;
            for i in 0..self.replicas.len() {
                while self.replicas[i].alive && !self.replicas[i].batcher.is_empty() {
                    self.dispatch(i);
                    progressed = true;
                }
            }
            let mut reroute: Vec<Request> = Vec::new();
            let mut first_err: Option<NexusError> = None;
            for i in 0..self.replicas.len() {
                while let Some(batch) = self.replicas[i].pending.pop_front() {
                    progressed = true;
                    let got = self.replicas[i].handle.get(&batch.call);
                    self.settle_batch(i, batch, got, &mut reroute, &mut first_err);
                }
            }
            for r in reroute {
                let j = self.pick_replica()?;
                self.replicas[j].batcher.push(r);
                progressed = true;
            }
            if let Some(e) = first_err {
                return Err(e);
            }
            if !progressed {
                return Ok(());
            }
        }
    }

    /// Drive an open-loop load through the plane: `requests` arrivals
    /// at `rate`/sec with deterministic exponential inter-arrivals
    /// drawn from `rng` (rate 0 = closed loop, i.e. enqueue as fast as
    /// the router accepts), generating each request's het features with
    /// `make_features`.  Ticks the plane while waiting so delay-based
    /// flushes and autoscaling stay live, then drains the tail.
    /// Returns the wall-clock seconds of the whole run including the
    /// drain.  Shared by `cmd_serve` and `benches/serve_latency.rs` so
    /// the CLI and the bench measure the identical arrival process.
    pub fn run_open_loop(
        &mut self,
        requests: usize,
        rate: f64,
        rng: &mut Pcg32,
        mut make_features: impl FnMut(&mut Pcg32) -> Vec<f32>,
    ) -> Result<f64> {
        let start = Instant::now();
        let mut next_arrival = 0.0f64;
        for _ in 0..requests {
            if rate > 0.0 {
                next_arrival += -(rng.f64().max(1e-12)).ln() / rate;
                while start.elapsed().as_secs_f64() < next_arrival {
                    self.tick()?;
                    std::thread::yield_now();
                }
            }
            let features = make_features(rng);
            self.enqueue(features)?;
        }
        self.drain()?;
        Ok(start.elapsed().as_secs_f64())
    }

    /// Serving statistics so far.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use std::time::Duration;

    fn model() -> CateModel {
        CateModel { theta: vec![1.0, 0.5], het: 1, block: 8, d_pad: 4 }
    }

    fn kx() -> Arc<dyn KernelExec> {
        Arc::new(HostBackend)
    }

    #[test]
    fn single_request_roundtrip() {
        let mut r = Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 1, max_delay: Duration::ZERO },
            RoutingPolicy::RoundRobin,
            1,
        )
        .unwrap();
        let id = r.enqueue(vec![2.0]).unwrap();
        r.drain().unwrap();
        let (rid, cate) = r.completed[0];
        assert_eq!(rid, id);
        assert!((cate - 2.0).abs() < 1e-6); // 1 + 0.5*2
    }

    #[test]
    fn batching_coalesces() {
        let mut r = Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 4, max_delay: Duration::from_secs(100) },
            RoutingPolicy::RoundRobin,
            1,
        )
        .unwrap();
        for i in 0..8 {
            r.enqueue(vec![i as f32]).unwrap();
        }
        r.drain().unwrap();
        let s = r.stats();
        assert_eq!(s.requests, 8);
        assert_eq!(s.batches, 2, "4+4");
        assert_eq!(s.mean_batch_size(), 4.0);
        assert_eq!(s.latency.len(), 8);
        // answers are correct per request
        for (id, cate) in &r.completed {
            assert!((cate - (1.0 + 0.5 * *id as f32)).abs() < 1e-5);
        }
    }

    #[test]
    fn rejects_short_features() {
        let mut r = Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 4, max_delay: Duration::ZERO },
            RoutingPolicy::RoundRobin,
            1,
        )
        .unwrap();
        assert!(r.enqueue(vec![]).is_err());
    }

    #[test]
    fn oversized_batch_policy_rejected_at_construction() {
        // model block is 8; a max_batch of 9 would only fail at flush
        // time without the constructor check
        let err = Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 9, max_delay: Duration::ZERO },
            RoutingPolicy::RoundRobin,
            1,
        );
        assert!(err.is_err());
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("max_batch"), "{msg}");
        // zero batches and zero replicas are config errors too
        assert!(Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 0, max_delay: Duration::ZERO },
            RoutingPolicy::RoundRobin,
            1,
        )
        .is_err());
        assert!(Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 4, max_delay: Duration::ZERO },
            RoutingPolicy::RoundRobin,
            0,
        )
        .is_err());
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut r = Router::new(
            model(),
            kx(),
            BatchPolicy { max_batch: 8, max_delay: Duration::from_secs(100) },
            RoutingPolicy::RoundRobin,
            4,
        )
        .unwrap();
        for i in 0..64 {
            r.enqueue(vec![i as f32]).unwrap();
        }
        r.drain().unwrap();
        assert_eq!(r.completed.len(), 64);
        for (_, dispatched, alive) in r.replica_loads() {
            assert!(alive);
            assert_eq!(dispatched, 16);
        }
    }

    #[test]
    fn least_outstanding_balances_and_p2c_uses_all() {
        for routing in [RoutingPolicy::LeastOutstanding, RoutingPolicy::PowerOfTwo] {
            let mut r = Router::new(
                model(),
                kx(),
                BatchPolicy { max_batch: 8, max_delay: Duration::from_secs(100) },
                routing,
                4,
            )
            .unwrap();
            for i in 0..400 {
                r.enqueue(vec![i as f32]).unwrap();
            }
            r.drain().unwrap();
            assert_eq!(r.completed.len(), 400);
            for (name, dispatched, _) in r.replica_loads() {
                assert!(dispatched > 0, "{} starved under {:?}", name, routing);
            }
        }
    }

    #[test]
    fn policy_names_roundtrip() {
        use RoutingPolicy::{LeastOutstanding, PowerOfTwo, RoundRobin};
        for p in [RoundRobin, LeastOutstanding, PowerOfTwo] {
            assert_eq!(RoutingPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RoutingPolicy::parse("bogus").is_err());
    }
}
