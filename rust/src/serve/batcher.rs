//! Dynamic batching: coalesce requests up to `max_batch` or `max_delay`,
//! whichever comes first — the standard serving trade-off (throughput
//! from batching vs tail latency from waiting).
//!
//! The queue is strictly FIFO: [`Batcher::take_batch`] always removes
//! the oldest requests, so request order is preserved end to end
//! (`tests/serve_props.rs` holds this as a property).  Each serving
//! replica owns one `Batcher`; the router decides when to flush by
//! polling [`Batcher::should_flush`].

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.  Must not exceed
    /// the served model's block size — `Router::new` validates this at
    /// construction.
    pub max_batch: usize,
    /// Flush once the oldest queued request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_delay: Duration::from_millis(5) }
    }
}

/// A pending request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Router-assigned id, unique per stream; completions carry it back.
    pub id: u64,
    /// Het features (at least the model's `het` values; extras ignored).
    pub features: Vec<f32>,
    /// When the request entered the plane — end-to-end latency is
    /// measured from here, surviving re-routes after replica failures.
    pub enqueued: Instant,
}

/// An accumulating FIFO batch queue for one replica.
#[derive(Debug, Default)]
pub struct Batcher {
    /// The flush policy (size + delay bounds).
    pub policy: BatchPolicy,
    queue: Vec<Request>,
}

impl Batcher {
    /// An empty batcher under `policy`.
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, queue: Vec::new() }
    }

    /// Append a request to the tail of the queue.
    pub fn push(&mut self, req: Request) {
        self.queue.push(req);
    }

    /// Requests currently queued.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should the current queue be flushed now?
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        now.duration_since(self.queue[0].enqueued) >= self.policy.max_delay
    }

    /// Take up to `max_batch` requests.
    pub fn take_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, at: Instant) -> Request {
        Request { id, features: vec![0.0; 4], enqueued: at }
    }

    #[test]
    fn flush_on_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_delay: Duration::from_secs(10) });
        let now = Instant::now();
        for i in 0..3 {
            b.push(req(i, now));
        }
        assert!(b.should_flush(now));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn flush_on_delay() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_delay: Duration::from_millis(1) });
        let past = Instant::now() - Duration::from_millis(5);
        b.push(req(0, past));
        assert!(b.should_flush(Instant::now()));
    }

    #[test]
    fn no_flush_when_fresh_and_small() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, max_delay: Duration::from_secs(1) });
        b.push(req(0, Instant::now()));
        assert!(!b.should_flush(Instant::now()));
        assert!(!b.should_flush(Instant::now()));
    }

    #[test]
    fn take_batch_respects_cap() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_delay: Duration::ZERO });
        let now = Instant::now();
        for i in 0..5 {
            b.push(req(i, now));
        }
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.len(), 3);
    }
}
