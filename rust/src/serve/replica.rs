//! Serving replicas: one deployed model per raylet actor.
//!
//! The paper's NEXUS platform deploys CATE models through Ray Serve
//! (§4); here each replica is a [`crate::raylet::actor`] actor — it
//! inherits the actor layer's serialized mailbox, fault injection
//! ([`crate::raylet::fault::FaultPlan`] via `spawn_with_faults`), and
//! crash semantics ([`crate::raylet::actor::ActorHandle::kill`]) for
//! free.  A replica owns a clone of the [`CateModel`] and answers one
//! mailbox message per batch; the [`crate::serve::router::Router`]
//! front-end owns batching, routing, and failover *around* the replica
//! set.

use std::sync::Arc;

use crate::error::{NexusError, Result};
use crate::raylet::actor::Actor;
use crate::raylet::payload::Payload;
use crate::runtime::backend::KernelExec;
use crate::serve::router::CateModel;

/// One serving replica: a deployed model + backend, driven by actor
/// messages.
///
/// Methods:
/// * `"predict"` — arg is a `[k, het]` tensor of packed het features;
///   returns `k` CATE predictions as floats.
/// * `"batches"` — returns the number of batches served (scalar).
pub struct ReplicaActor {
    model: CateModel,
    kx: Arc<dyn KernelExec>,
    batches: u64,
}

impl ReplicaActor {
    /// Deploy `model` on `kx` as a replica (spawn it with
    /// [`crate::raylet::actor::spawn`]).
    pub fn new(model: CateModel, kx: Arc<dyn KernelExec>) -> ReplicaActor {
        ReplicaActor { model, kx, batches: 0 }
    }
}

impl Actor for ReplicaActor {
    fn handle(&mut self, method: &str, arg: Payload) -> Result<Payload> {
        match method {
            "predict" => {
                let t = arg.as_tensor()?;
                if t.shape.len() != 2 || t.shape[1] != self.model.het {
                    return Err(NexusError::Serve(format!(
                        "replica expects a [k, {}] feature tensor, got shape {:?}",
                        self.model.het, t.shape
                    )));
                }
                let k = t.shape[0];
                let preds = self.model.predict_block(self.kx.as_ref(), &t.data, k)?;
                self.batches += 1;
                Ok(Payload::Floats(preds))
            }
            "batches" => Ok(Payload::Scalar(self.batches as f64)),
            other => Err(NexusError::Serve(format!("replica has no method '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::raylet::actor::spawn;
    use crate::runtime::backend::HostBackend;
    use crate::runtime::tensor::Tensor;

    fn model() -> CateModel {
        CateModel { theta: vec![1.0, 0.5], het: 1, block: 8, d_pad: 4 }
    }

    #[test]
    fn replica_serves_batches_through_its_mailbox() {
        let a = spawn("replica-test", ReplicaActor::new(model(), Arc::new(HostBackend)));
        let out = a
            .ask(
                "predict",
                Payload::Tensor(Tensor { shape: vec![3, 1], data: vec![0.0, 1.0, 2.0] }),
            )
            .unwrap();
        let preds = out.as_floats().unwrap().to_vec();
        assert_eq!(preds.len(), 3);
        for (i, p) in preds.iter().enumerate() {
            assert!((p - (1.0 + 0.5 * i as f32)).abs() < 1e-6, "{preds:?}");
        }
        let served = a.ask("batches", Payload::Empty).unwrap().as_scalar().unwrap();
        assert_eq!(served, 1.0);
    }

    #[test]
    fn replica_rejects_bad_shapes_and_methods_without_dying() {
        let a = spawn("replica-test", ReplicaActor::new(model(), Arc::new(HostBackend)));
        // wrong feature width
        assert!(a
            .ask("predict", Payload::Tensor(Tensor { shape: vec![2, 3], data: vec![0.0; 6] }))
            .is_err());
        // batch bigger than the model block
        assert!(a
            .ask("predict", Payload::Tensor(Tensor { shape: vec![9, 1], data: vec![0.0; 9] }))
            .is_err());
        // unknown method
        assert!(a.ask("nope", Payload::Empty).is_err());
        // still alive and serving
        assert!(a
            .ask("predict", Payload::Tensor(Tensor { shape: vec![1, 1], data: vec![4.0] }))
            .is_ok());
    }
}
