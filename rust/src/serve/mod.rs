//! CATE serving — the Ray Serve slice of the NEXUS platform (§4:
//! "efficient deployment and autoscaling capabilities using Ray Serve").
//!
//! The serving plane has three layers:
//!
//! * [`batcher`] — dynamic batching: coalesce single-row requests up to
//!   `max_batch` or `max_delay`, whichever comes first.  One batcher
//!   per replica.
//! * [`replica`] — a deployed model as a raylet actor; each replica
//!   executes padded predict blocks on its own OS thread and inherits
//!   the actor layer's fault injection and crash semantics.
//! * [`router`] — the front-end: routes requests over the replica set
//!   (round-robin / least-outstanding / power-of-two-choices), collects
//!   results without blocking, re-routes around dead replicas, tracks
//!   p50/p95/p99 latency, and optionally autoscales the replica count
//!   from queue depth.
//!
//! `benches/serve_latency.rs` sweeps arrival rate x replica count x
//! routing policy through this stack and writes
//! `BENCH_serve_latency.json`.

pub mod batcher;
pub mod replica;
pub mod router;

pub use batcher::{BatchPolicy, Batcher};
pub use replica::ReplicaActor;
pub use router::{CateModel, Router, RoutingPolicy, ServeStats};
