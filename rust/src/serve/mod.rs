//! CATE serving — the Ray Serve slice of the NEXUS platform (§4:
//! "efficient deployment and autoscaling capabilities using Ray Serve").
//!
//! [`batcher`] coalesces single-row requests into padded blocks for the
//! compiled predict artifact; [`router`] owns replica dispatch and
//! latency accounting.

pub mod batcher;
pub mod router;

pub use batcher::{BatchPolicy, Batcher};
pub use router::{CateModel, Router, ServeStats};
