//! Kernel-core microbench: SIMD vs scalar-blocked vs the naive oracle.
//!
//! Three layers are timed on the fused masked-gram kernel — the op the
//! DML hot loop spends its time in:
//!
//! * `naive`  — single-threaded oracle loops (`linalg::graphs`)
//! * `blocked` — cache-tiled + threaded core, **scalar** dispatch
//! * `simd`   — the same core with this machine's SIMD dispatch
//!   (`linalg::simd`, AVX2+FMA / NEON; equals `blocked` when the CPU
//!   has neither)
//!
//! The sweep covers block shape (n x d) x tile width x thread count;
//! a dedicated large shape (65536 x 256) gates the SIMD speedup, and
//! the row-dot kernels (`mat_vec`, `xt_v`) get per-kernel scalar-vs-simd
//! rows so the dispatch win is visible beyond gram.  Every timed
//! configuration is also checked bit-identical to the oracle, so a perf
//! run doubles as a determinism check.
//!
//! Results append to `BENCH_linalg_kernels.json` (one session per
//! invocation) so the perf trajectory is tracked across PRs.
//!
//!     cargo bench --offline --bench linalg_kernels
//!     NEXUS_BENCH_QUICK=1 ...   (smaller shapes, fewer reps — CI)
//!     NEXUS_PERF_SMOKE=1 ...    (exit 1 if blocked <= naive, or SIMD
//!                                < 1.5x scalar-blocked at d >= 256)

use std::time::Instant;

use nexus::bench_support::Table;
use nexus::data::matrix::Matrix;
use nexus::linalg;
use nexus::linalg::blocked::KernelOpts;
use nexus::linalg::simd::{self, Dispatch, SimdMode};
use nexus::models::cost::CostModel;
use nexus::util::json::Json;
use nexus::util::rng::Pcg32;

fn block(seed: u64, n: usize, d: usize) -> (Matrix, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::new(seed);
    let x = Matrix::from_fn(n, d, |_, _| rng.normal_f32());
    let y: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let mask: Vec<f32> = (0..n).map(|i| if i % 13 == 0 { 0.0 } else { 1.0 }).collect();
    (x, y, mask)
}

/// Min-over-reps seconds for one invocation of `f`.
fn time_min(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let smoke = std::env::var("NEXUS_PERF_SMOKE").is_ok();
    let reps = if quick { 3 } else { 5 };
    let shapes: &[(usize, usize)] = if quick {
        &[(1024, 128), (1024, 256)]
    } else {
        &[(4096, 128), (4096, 256), (4096, 512)]
    };
    let tiles: &[usize] = if quick { &[32, 64] } else { &[32, 64, 128] };
    let max_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let threads: Vec<usize> =
        [1usize, 2, 4, 8].iter().copied().filter(|&t| t == 1 || t <= max_threads).collect();
    let auto_dsp = simd::dispatch_for(SimdMode::Auto);

    let mut tbl = Table::new(
        "Blocked kernel core — fused masked gram, GFLOP/s (speedup vs naive / simd vs scalar)",
        &["n", "d", "tile", "threads", "naive", "blocked", "simd", "speedup", "simd_x", "disp"],
    );
    let mut records: Vec<Json> = Vec::new();
    // speedup of the best scalar-blocked config vs naive, per shape —
    // the original perf-smoke gate uses the worst shape
    let mut smoke_worst = f64::INFINITY;
    // best-simd vs best-scalar-blocked per shape with d >= 256 — the
    // SIMD gate uses the worst such shape (plus the large shape below)
    let mut simd_gate_worst = f64::INFINITY;

    for &(n, d) in shapes {
        let (x, y, mask) = block(n as u64 * 31 + d as u64, n, d);
        let flops = CostModel::gram_flops(n, d);

        let naive_secs = time_min(reps, || {
            let _ = linalg::graphs::gram_block(&x, &y, &mask).unwrap();
        });
        let naive_gflops = flops / naive_secs / 1e9;

        // determinism spot-check once per shape: blocked output at an
        // awkward tile must equal the oracle bitwise, at BOTH dispatches
        {
            let (g0, b0, n0) = linalg::graphs::gram_block(&x, &y, &mask)?;
            for dsp in [Dispatch::Scalar, auto_dsp] {
                let opts =
                    KernelOpts { threads: max_threads, tile_cols: 48, tile_rows: 1000, simd: dsp };
                let st = linalg::blocked::gram_block_with(&x, &y, &mask, &opts)?;
                assert_eq!(
                    st.g.data(),
                    g0.data(),
                    "blocked({dsp:?}) gram differs from oracle at {n}x{d}"
                );
                assert_eq!(st.xty, b0);
                assert_eq!(st.n, n0);
            }
        }

        let mut best_speedup = 0.0f64;
        let mut best_scalar = 0.0f64;
        let mut best_simd = 0.0f64;
        for &tile in tiles {
            for &t in &threads {
                let opts = KernelOpts {
                    threads: t,
                    tile_cols: tile,
                    tile_rows: 2048,
                    simd: Dispatch::Scalar,
                };
                let secs = time_min(reps, || {
                    let _ = linalg::blocked::gram_block_with(&x, &y, &mask, &opts).unwrap();
                });
                let sopts = KernelOpts { simd: auto_dsp, ..opts };
                let simd_secs = if auto_dsp == Dispatch::Scalar {
                    secs
                } else {
                    time_min(reps, || {
                        let _ = linalg::blocked::gram_block_with(&x, &y, &mask, &sopts).unwrap();
                    })
                };
                let gflops = flops / secs / 1e9;
                let simd_gflops = flops / simd_secs / 1e9;
                let speedup = naive_secs / secs;
                let simd_speedup = secs / simd_secs;
                best_speedup = best_speedup.max(speedup);
                best_scalar = best_scalar.max(gflops);
                best_simd = best_simd.max(simd_gflops);
                tbl.row(vec![
                    format!("{n}"),
                    format!("{d}"),
                    format!("{tile}"),
                    format!("{t}"),
                    format!("{naive_gflops:.2}"),
                    format!("{gflops:.2}"),
                    format!("{simd_gflops:.2}"),
                    format!("{speedup:.2}x"),
                    format!("{simd_speedup:.2}x"),
                    auto_dsp.name().to_string(),
                ]);
                records.push(
                    Json::obj()
                        .set("kernel", "gram")
                        .set("n", n)
                        .set("d", d)
                        .set("tile", tile)
                        .set("threads", t)
                        .set("naive_gflops", naive_gflops)
                        .set("blocked_gflops", gflops)
                        .set("simd_gflops", simd_gflops)
                        .set("speedup", speedup)
                        .set("simd_speedup", simd_speedup)
                        .set("dispatch", auto_dsp.name()),
                );
            }
        }
        smoke_worst = smoke_worst.min(best_speedup);
        if d >= 256 && best_scalar > 0.0 {
            simd_gate_worst = simd_gate_worst.min(best_simd / best_scalar);
        }
    }
    tbl.print();

    // ---- large-shape SIMD gate + per-kernel dispatch rows ----
    // The acceptance shape (65536 x 256) is timed in every mode, but
    // only scalar-blocked vs simd (the naive oracle would dominate CI
    // time); mat_vec / xt_v get one row each so the row-dot and
    // column-axpy microkernels are tracked per kernel too.
    let (gn, gd) = (65_536usize, 256usize);
    let gate_reps = if quick { 2 } else { 3 };
    let (gx, gy, gmask) = block(991, gn, gd);
    let gopts =
        KernelOpts { threads: max_threads, tile_cols: 64, tile_rows: 2048, simd: Dispatch::Scalar };
    let gsopts = KernelOpts { simd: auto_dsp, ..gopts };
    let beta: Vec<f32> = (0..gd).map(|j| ((j as f32) * 0.1).sin()).collect();

    let mut kernel_rows: Vec<(String, f64, f64, f64)> = Vec::new();
    let gate_speedup = {
        let scalar_secs = time_min(gate_reps, || {
            let _ = linalg::blocked::gram_block_with(&gx, &gy, &gmask, &gopts).unwrap();
        });
        let simd_secs = if auto_dsp == Dispatch::Scalar {
            scalar_secs
        } else {
            time_min(gate_reps, || {
                let _ = linalg::blocked::gram_block_with(&gx, &gy, &gmask, &gsopts).unwrap();
            })
        };
        let flops = CostModel::gram_flops(gn, gd);
        kernel_rows.push((
            "gram".into(),
            flops / scalar_secs / 1e9,
            flops / simd_secs / 1e9,
            scalar_secs / simd_secs,
        ));
        // bitwise parity on the gate shape
        let a = linalg::blocked::gram_block_with(&gx, &gy, &gmask, &gopts)?;
        let b = linalg::blocked::gram_block_with(&gx, &gy, &gmask, &gsopts)?;
        assert_eq!(a.g.data(), b.g.data(), "simd gram differs from scalar at {gn}x{gd}");
        scalar_secs / simd_secs
    };
    {
        let flops = 2.0 * gn as f64 * gd as f64;
        let scalar_secs = time_min(gate_reps, || {
            let _ = linalg::blocked::mat_vec_with(&gx, &beta, &gopts).unwrap();
        });
        let simd_secs = time_min(gate_reps, || {
            let _ = linalg::blocked::mat_vec_with(&gx, &beta, &gsopts).unwrap();
        });
        kernel_rows.push((
            "mat_vec".into(),
            flops / scalar_secs / 1e9,
            flops / simd_secs / 1e9,
            scalar_secs / simd_secs,
        ));
        let scalar_secs = time_min(gate_reps, || {
            let _ = linalg::blocked::xt_v_with(&gx, &gy, &gopts).unwrap();
        });
        let simd_secs = time_min(gate_reps, || {
            let _ = linalg::blocked::xt_v_with(&gx, &gy, &gsopts).unwrap();
        });
        kernel_rows.push((
            "xt_v".into(),
            flops / scalar_secs / 1e9,
            flops / simd_secs / 1e9,
            scalar_secs / simd_secs,
        ));
    }
    println!(
        "\nper-kernel dispatch at {gn}x{gd} (threads={max_threads}, dispatch={}):",
        auto_dsp.name()
    );
    for (kernel, scalar_gflops, simd_gflops, simd_speedup) in &kernel_rows {
        println!(
            "  {kernel:>8}: scalar {scalar_gflops:6.2} GFLOP/s | simd {simd_gflops:6.2} GFLOP/s | {simd_speedup:.2}x"
        );
        records.push(
            Json::obj()
                .set("kernel", kernel.as_str())
                .set("n", gn)
                .set("d", gd)
                .set("tile", 64usize)
                .set("threads", max_threads)
                .set("scalar_gflops", *scalar_gflops)
                .set("simd_gflops", *simd_gflops)
                .set("simd_speedup", *simd_speedup)
                .set("dispatch", auto_dsp.name()),
        );
    }
    simd_gate_worst = simd_gate_worst.min(gate_speedup);

    let path = std::path::Path::new("BENCH_linalg_kernels.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    sessions.push(
        Json::obj()
            .set("quick", quick)
            .set("machine_threads", max_threads)
            .set("dispatch", auto_dsp.name())
            .set("worst_shape_best_speedup", smoke_worst)
            .set("simd_gate_speedup", simd_gate_worst)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj()
        .set("bench", "linalg_kernels")
        .set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!("\nwrote BENCH_linalg_kernels.json ({n_sessions} sessions total)");

    if smoke {
        // perf gate 1: at every shape the best blocked config must beat
        // the naive loops outright (5% slack for timer noise)
        if smoke_worst < 1.05 {
            eprintln!(
                "PERF SMOKE FAILED: best blocked speedup {smoke_worst:.2}x < 1.05x — \
                 the blocked kernel core is not beating the naive oracle"
            );
            std::process::exit(1);
        }
        println!("perf smoke passed: worst-shape best speedup {smoke_worst:.2}x");
        // perf gate 2: SIMD must beat scalar-blocked by >= 1.5x at
        // d >= 256 (skipped when this machine has no SIMD dispatch)
        if auto_dsp == Dispatch::Scalar {
            eprintln!(
                "perf smoke: no SIMD dispatch on this machine — skipping the 1.5x SIMD gate"
            );
        } else if simd_gate_worst < 1.5 {
            eprintln!(
                "PERF SMOKE FAILED: SIMD gram speedup {simd_gate_worst:.2}x < 1.5x over the \
                 scalar blocked path at d >= 256 (dispatch={})",
                auto_dsp.name()
            );
            std::process::exit(1);
        } else {
            println!(
                "perf smoke passed: SIMD gram {simd_gate_worst:.2}x over scalar blocked at d >= 256"
            );
        }
    }
    Ok(())
}
