//! Kernel-core microbench: blocked/threaded gram vs the naive oracle.
//!
//! Sweeps block shape (n x d) x tile width x thread count over the fused
//! masked-gram kernel (`linalg::blocked::gram_block`) — the op the DML
//! hot loop spends its time in — and records GFLOP/s plus the speedup
//! over the single-threaded naive loops (`linalg::graphs::gram_block`).
//! Every timed configuration is also checked bit-identical to the
//! oracle, so a perf run doubles as a determinism check.
//!
//! Results append to `BENCH_linalg_kernels.json` (one session per
//! invocation) so the perf trajectory is tracked across PRs.
//!
//!     cargo bench --offline --bench linalg_kernels
//!     NEXUS_BENCH_QUICK=1 ...   (smaller shapes, fewer reps — CI)
//!     NEXUS_PERF_SMOKE=1 ...    (exit 1 if blocked is slower than naive)

use std::time::Instant;

use nexus::bench_support::Table;
use nexus::data::matrix::Matrix;
use nexus::linalg;
use nexus::linalg::blocked::KernelOpts;
use nexus::models::cost::CostModel;
use nexus::util::json::Json;
use nexus::util::rng::Pcg32;

fn block(seed: u64, n: usize, d: usize) -> (Matrix, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::new(seed);
    let x = Matrix::from_fn(n, d, |_, _| rng.normal_f32());
    let y: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let mask: Vec<f32> = (0..n).map(|i| if i % 13 == 0 { 0.0 } else { 1.0 }).collect();
    (x, y, mask)
}

/// Min-over-reps seconds for one invocation of `f`.
fn time_min(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let smoke = std::env::var("NEXUS_PERF_SMOKE").is_ok();
    let reps = if quick { 3 } else { 5 };
    let shapes: &[(usize, usize)] =
        if quick { &[(1024, 128), (1024, 256)] } else { &[(4096, 128), (4096, 256), (4096, 512)] };
    let tiles: &[usize] = if quick { &[32, 64] } else { &[32, 64, 128] };
    let max_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let threads: Vec<usize> =
        [1usize, 2, 4, 8].iter().copied().filter(|&t| t == 1 || t <= max_threads).collect();

    let mut tbl = Table::new(
        "Blocked kernel core — fused masked gram, GFLOP/s (speedup vs naive)",
        &["n", "d", "tile", "threads", "naive", "blocked", "speedup"],
    );
    let mut records: Vec<Json> = Vec::new();
    // speedup of the best blocked config vs naive, per shape — the
    // perf-smoke gate uses the worst shape
    let mut smoke_worst = f64::INFINITY;

    for &(n, d) in shapes {
        let (x, y, mask) = block(n as u64 * 31 + d as u64, n, d);
        let flops = CostModel::gram_flops(n, d);

        let naive_secs = time_min(reps, || {
            let _ = linalg::graphs::gram_block(&x, &y, &mask).unwrap();
        });
        let naive_gflops = flops / naive_secs / 1e9;

        // determinism spot-check once per shape: blocked output at an
        // awkward tile must equal the oracle bitwise
        {
            let (g0, b0, n0) = linalg::graphs::gram_block(&x, &y, &mask)?;
            let opts = KernelOpts { threads: max_threads, tile_cols: 48, tile_rows: 1000 };
            let st = linalg::blocked::gram_block_with(&x, &y, &mask, &opts)?;
            assert_eq!(st.g.data(), g0.data(), "blocked gram differs from oracle at {n}x{d}");
            assert_eq!(st.xty, b0);
            assert_eq!(st.n, n0);
        }

        let mut best_speedup = 0.0f64;
        for &tile in tiles {
            for &t in &threads {
                let opts = KernelOpts { threads: t, tile_cols: tile, tile_rows: 2048 };
                let secs = time_min(reps, || {
                    let _ = linalg::blocked::gram_block_with(&x, &y, &mask, &opts).unwrap();
                });
                let gflops = flops / secs / 1e9;
                let speedup = naive_secs / secs;
                best_speedup = best_speedup.max(speedup);
                tbl.row(vec![
                    format!("{n}"),
                    format!("{d}"),
                    format!("{tile}"),
                    format!("{t}"),
                    format!("{naive_gflops:.2}"),
                    format!("{gflops:.2}"),
                    format!("{speedup:.2}x"),
                ]);
                records.push(
                    Json::obj()
                        .set("n", n)
                        .set("d", d)
                        .set("tile", tile)
                        .set("threads", t)
                        .set("naive_gflops", naive_gflops)
                        .set("blocked_gflops", gflops)
                        .set("speedup", speedup),
                );
            }
        }
        smoke_worst = smoke_worst.min(best_speedup);
    }
    tbl.print();

    let path = std::path::Path::new("BENCH_linalg_kernels.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    sessions.push(
        Json::obj()
            .set("quick", quick)
            .set("machine_threads", max_threads)
            .set("worst_shape_best_speedup", smoke_worst)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj()
        .set("bench", "linalg_kernels")
        .set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!("\nwrote BENCH_linalg_kernels.json ({n_sessions} sessions total)");

    if smoke {
        // perf gate: at every shape the best blocked config must beat the
        // naive loops outright (5% slack for timer noise on tiny shapes)
        if smoke_worst < 1.05 {
            eprintln!(
                "PERF SMOKE FAILED: best blocked speedup {smoke_worst:.2}x < 1.05x — \
                 the blocked kernel core is not beating the naive oracle"
            );
            std::process::exit(1);
        }
        println!("perf smoke passed: worst-shape best speedup {smoke_worst:.2}x");
    }
    Ok(())
}
