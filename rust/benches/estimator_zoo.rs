//! Estimator-zoo bench: every sharded estimator (S/T/X metalearners,
//! cross-fit AIPW, entropy balancing) swept over n × workers.
//!
//! Two numbers matter per cell: wall-clock (does the task fan-out
//! scale?) and the estimate itself (did distribution move a bit?).
//! The second is enforced in-run: every sharded fit is bit-compared
//! against the materialized-adapter baseline on the inline executor —
//! the speedup table is void if any cell's ATE differs in even one
//! mantissa bit, so the guard asserts rather than records.
//!
//! Every run is appended to `BENCH_estimator_zoo.json`
//! (EXPERIMENTS.md documents the schema).
//!
//!     cargo bench --offline --bench estimator_zoo
//!     NEXUS_BENCH_QUICK=1 ...  (tiny sweep for CI)

use std::sync::Arc;
use std::time::Instant;

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::{balancing, dr, metalearners};
use nexus::data::dataset::ShardedDataset;
use nexus::data::synth::{generate, CausalDataset, SynthConfig};
use nexus::models::cost::CostModel;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::{backend_by_name, KernelExec};
use nexus::util::json::Json;

const LAM: f32 = 1e-3;
const CLIP: f32 = 0.01;
const BAL_ITERS: usize = 12;
const BAL_RIDGE: f32 = 1e-6;

/// CATE-dispersion SE proxy for the metalearners (no influence fn).
fn meta_se(ate: f64, cate: &[f32]) -> f64 {
    let n = cate.len() as f64;
    let mut ss = 0.0f64;
    for &c in cate {
        ss += (c as f64 - ate).powi(2);
    }
    (ss / (n - 1.0).max(1.0) / n).sqrt()
}

/// Materialized-adapter fit on the given executor: the parity anchor.
fn fit_adapter(
    est: &str,
    ctx: &RayContext,
    kx: Arc<dyn KernelExec>,
    ds: &CausalDataset,
    block: usize,
    seed: u64,
) -> nexus::Result<(f64, f64)> {
    Ok(match est {
        "s" => {
            let f = metalearners::s_learner(ctx, kx, ds, LAM, block)?;
            (f.ate, meta_se(f.ate, &f.cate))
        }
        "t" => {
            let f = metalearners::t_learner(ctx, kx, ds, LAM, block)?;
            (f.ate, meta_se(f.ate, &f.cate))
        }
        "x" => {
            let f = metalearners::x_learner(ctx, kx, ds, LAM, block)?;
            (f.ate, meta_se(f.ate, &f.cate))
        }
        "dr" => {
            let f = dr::fit(ctx, kx, ds, 5, LAM, CLIP, block, seed)?;
            (f.ate.value, f.ate.se)
        }
        _ => {
            let f = balancing::fit(ctx, kx, ds, BAL_ITERS, BAL_RIDGE, block)?;
            (f.ate.value, f.ate.se)
        }
    })
}

/// Store-resident fit: same estimator directly on the sharded plane.
fn fit_sharded(
    est: &str,
    ctx: &RayContext,
    kx: Arc<dyn KernelExec>,
    cost: &CostModel,
    sds: &ShardedDataset,
    d_real: usize,
    seed: u64,
) -> nexus::Result<(f64, f64)> {
    Ok(match est {
        "s" | "t" | "x" => {
            let cfg = metalearners::MetaConfig { lam: LAM, irls_iters: 5, d_real };
            let f = match est {
                "s" => metalearners::s_learner_sharded(ctx, kx, cost, sds, &cfg)?,
                "t" => metalearners::t_learner_sharded(ctx, kx, cost, sds, &cfg)?,
                _ => metalearners::x_learner_sharded(ctx, kx, cost, sds, &cfg)?,
            };
            (f.ate, meta_se(f.ate, &f.cate))
        }
        "dr" => {
            let cfg = dr::DrConfig { cv: 5, lam: LAM, clip: CLIP, irls_iters: 5, seed, d_real };
            let f = dr::fit_sharded(ctx, kx, cost, sds, &cfg)?;
            (f.ate.value, f.ate.se)
        }
        _ => {
            let cfg = balancing::BalancingConfig { iters: BAL_ITERS, ridge: BAL_RIDGE, d_real };
            let f = balancing::fit_sharded(ctx, kx, cost, sds, &cfg)?;
            (f.ate.value, f.ate.se)
        }
    })
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let kx: Arc<dyn KernelExec> = backend_by_name("host")?;
    let cost = CostModel::default();
    let seed = 123u64;
    let d = 8usize;
    let d_pad = (d + 1).next_power_of_two().max(8);
    let ests = ["s", "t", "x", "dr", "balancing"];
    let ns: &[usize] = if quick { &[2_000] } else { &[20_000, 100_000] };
    let workers: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };

    let mut records: Vec<Json> = Vec::new();
    let mut tbl = Table::new(
        "Estimator zoo — sharded plane, estimator × n × workers (parity-guarded)",
        &["estimator", "n", "workers", "ATE", "SE", "tasks", "wall", "parity"],
    );
    for &n in ns {
        let ds = generate(&SynthConfig { n, d, seed, ..Default::default() });
        let block = if n >= 50_000 { 4096 } else { 256 };
        let driver_block_bytes = 4 * (block * d_pad + 3 * block);
        for est in ests {
            // the anchor: materialized adapter, inline executor
            let (base_ate, base_se) =
                fit_adapter(est, &RayContext::inline(), kx.clone(), &ds, block, seed)?;
            for &w in workers {
                let ctx = RayContext::threads(w);
                let t0 = Instant::now();
                let sds = ShardedDataset::from_materialized(&ctx, &ds, d_pad, block)?;
                let (ate, se) = fit_sharded(est, &ctx, kx.clone(), &cost, &sds, d, seed)?;
                ctx.drain()?;
                let wall = t0.elapsed().as_secs_f64();
                let m = ctx.metrics();
                // the in-run equality guard: distribution may not move a bit
                assert_eq!(
                    base_ate.to_bits(),
                    ate.to_bits(),
                    "{est}: sharded ATE != materialized at n={n} workers={w}"
                );
                assert_eq!(
                    base_se.to_bits(),
                    se.to_bits(),
                    "{est}: sharded SE != materialized at n={n} workers={w}"
                );
                tbl.row(vec![
                    est.to_string(),
                    format!("{n}"),
                    format!("{w}"),
                    format!("{ate:.4}"),
                    format!("{se:.4}"),
                    format!("{}", m.tasks_run),
                    fmt_secs(wall),
                    "bit-equal".into(),
                ]);
                records.push(
                    Json::obj()
                        .set("kind", "zoo")
                        .set("estimator", est)
                        .set("n", n)
                        .set("d", d)
                        .set("d_pad", d_pad)
                        .set("block", block)
                        .set("workers", w)
                        .set("ate", ate)
                        .set("se", se)
                        .set("true_ate", ds.true_ate())
                        .set("tasks", m.tasks_run as i64)
                        .set("driver_block_bytes", driver_block_bytes)
                        .set("peak_store_bytes", m.peak_store_bytes as i64)
                        .set("wall_secs", wall)
                        .set("parity", true),
                );
            }
        }
    }
    tbl.print();

    // append this invocation as one session (same pattern as fig6)
    let path = std::path::Path::new("BENCH_estimator_zoo.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    let n_runs = records.len();
    sessions.push(
        Json::obj()
            .set("backend", kx.name())
            .set("quick", quick)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj()
        .set("bench", "estimator_zoo")
        .set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!(
        "\nwrote BENCH_estimator_zoo.json ({n_runs} runs this session, {n_sessions} sessions total)"
    );
    Ok(())
}
