//! Figure 6 reproduction: DML vs DML_Ray runtime at 10k / 100k / 1M
//! treated units x ~500 covariates on a 5-node cluster (paper §5.3).
//!
//! Method (DESIGN.md §3, §5): this box has one core, so the cluster is
//! simulated — task costs are CALIBRATED from real PJRT kernel
//! executions on this machine, then the schedule runs under a virtual
//! clock.  Part A validates the simulator: a real sequential run at 10k
//! is compared against the 1-node-1-slot virtual makespan, and a real
//! thread-pool run tracks the wall-clock of the locality-aware
//! scheduler.  Part B regenerates the figure's series at all three
//! scales.
//!
//! Every run is appended to `BENCH_dml_runtime.json` (machine-readable:
//! mode, workers, makespan, busy/overhead/transfer secs, spills) so the
//! perf trajectory is tracked across PRs.
//!
//!     cargo bench --offline --bench fig6_dml_runtime
//!     NEXUS_BENCH_QUICK=1 ... (skips the real 10k x 500 validation run)

use std::time::Instant;

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::dml;
use nexus::config::ClusterConfig;
use nexus::data::synth::{generate, SynthConfig};
use nexus::linalg::simd::{self, SimdMode};
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::{ExecOpts, Metrics, RayContext, SpecPolicy};
use nexus::raylet::fault::FaultPlan;
use nexus::runtime::backend::backend_by_name;
use nexus::util::json::Json;

fn ccfg(n: usize, d: usize, d_pad: usize) -> CrossfitConfig {
    CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: if n / 5 > 2048 { 4096 } else { 256 },
        d_pad,
        d_real: d,
        seed: 123,
        stratified: false,
        reuse_suffstats: false,
    }
}

/// One machine-readable benchmark record.
fn record(mode: &str, workers: usize, n: usize, d: usize, m: &Metrics) -> Json {
    Json::obj()
        .set("mode", mode)
        .set("workers", workers)
        .set("n", n)
        .set("d", d)
        .set("makespan_secs", m.makespan)
        .set("busy_secs", m.busy_secs)
        .set("overhead_secs", m.overhead_secs)
        .set("transfer_secs", m.transfer_secs)
        .set("tasks", m.tasks_run as i64)
        .set("retries", m.retries as i64)
        .set("spills", m.spills as i64)
        .set("peak_store_bytes", m.peak_store_bytes as i64)
        .set("bytes_transferred", m.bytes_transferred as i64)
        .set("steals", m.steals as i64)
        .set("spec_launched", m.spec_launched as i64)
        .set("spec_wins", m.spec_wins as i64)
        .set("spec_losses", m.spec_losses as i64)
        .set("driver_block_bytes", m.driver_block_bytes as i64)
        .set("shuffle_bytes", m.shuffle_bytes as i64)
        .set("cost_dollars", m.cost_dollars)
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let d = 500;
    let d_pad = 512;
    let mut records: Vec<Json> = Vec::new();

    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    println!("backend: {}", kx.name());
    // calibrate the virtual-time cost model from real kernel executions
    let cost = CostModel::calibrate(kx.as_ref(), 256, d_pad);
    println!(
        "calibrated cost model: {:.2} GFLOP/s effective, {:.0}us fixed/task",
        cost.gflops,
        cost.task_fixed * 1e6
    );

    // ---- kernel core: blocked vs naive at the threads-mode workload
    // shape (the gram block the 1M x 500 run spends its time in).  Both
    // rates land in the session record so the speedup is checkable from
    // one run of the artifact.
    let (cb, cd) = if quick { (1024, 512) } else { (4096, 512) };
    let blocked_cal = CostModel::calibrate(backend_by_name("host")?.as_ref(), cb, cd);
    let naive_cal = CostModel::calibrate(backend_by_name("host-naive")?.as_ref(), cb, cd);
    let kernel_speedup = blocked_cal.gflops / naive_cal.gflops;
    // re-calibrate with SIMD dispatch forced off so the session record
    // separates the tiling/threading win from the microkernel win; the
    // global mode is restored to auto (env-respecting) right after
    simd::set_simd_mode(SimdMode::Off);
    let scalar_cal = CostModel::calibrate(backend_by_name("host")?.as_ref(), cb, cd);
    simd::set_simd_mode(SimdMode::Auto);
    let simd_dispatch = simd::current_dispatch();
    let simd_speedup = blocked_cal.gflops / scalar_cal.gflops;
    println!(
        "kernel core at ({cb} x {cd}): blocked[{}] {:.2} GFLOP/s vs scalar-blocked {:.2} GFLOP/s \
         ({simd_speedup:.2}x) vs naive {:.2} GFLOP/s => {kernel_speedup:.1}x",
        simd_dispatch.name(),
        blocked_cal.gflops,
        scalar_cal.gflops,
        naive_cal.gflops
    );

    // ---- Part A: simulator validation at 10k x 500 (real vs virtual) ----
    if !quick {
        let n = 10_000;
        let ds = generate(&SynthConfig { n, d, seed: 123, ..Default::default() });
        let cfg = ccfg(n, d, d_pad);
        let t0 = Instant::now();
        let ctx = RayContext::inline();
        let fit = dml::fit_with(&ctx, kx.clone(), &cost, &ds, &cfg, 1, 2)?;
        let real_seq = t0.elapsed().as_secs_f64();
        records.push(record("inline", 1, n, d, &ctx.metrics()));
        let sim_seq = {
            let ctx = RayContext::sim(
                ClusterConfig { nodes: 1, slots_per_node: 1, ..Default::default() },
                false,
            );
            dml::fit_dry(&ctx, &cost, n, &cfg, 2)?.makespan
        };
        println!(
            "\n[validation] 10k x {d}: real sequential {} vs simulated 1x1 {} (ratio {:.2}) | ATE={:.3}",
            fmt_secs(real_seq),
            fmt_secs(sim_seq),
            real_seq / sim_seq,
            fit.ate.value
        );
    }

    // ---- Part A2: real thread-pool run (locality-aware scheduler) --------
    {
        let (tn, td) = if quick { (4_000, 50) } else { (10_000, d) };
        let td_pad = (td + 1).next_power_of_two().clamp(16, 512);
        let workers = 4;
        let ds = generate(&SynthConfig { n: tn, d: td, seed: 123, ..Default::default() });
        let cfg = ccfg(tn, td, td_pad);
        let ctx = RayContext::threads(workers);
        let t0 = Instant::now();
        let fit = dml::fit_with(&ctx, kx.clone(), &cost, &ds, &cfg, 1, 2)?;
        let wall = t0.elapsed().as_secs_f64();
        let m = ctx.metrics();
        println!(
            "\n[threads] {tn} x {td} on {workers} workers: wall {} | busy {} | dispatch {} | ATE={:.3}",
            fmt_secs(wall),
            fmt_secs(m.busy_secs),
            fmt_secs(m.overhead_secs),
            fit.ate.value
        );
        records.push(record("threads", workers, tn, td, &m));
    }

    // ---- Part B: the figure ----------------------------------------------
    let cluster = ClusterConfig::default(); // 5 nodes x 8 slots (paper)
    let scales: &[usize] = if quick { &[10_000, 100_000] } else { &[10_000, 100_000, 1_000_000] };

    let mut tbl = Table::new(
        "Figure 6 — DML vs DML_Ray runtime (virtual seconds, calibrated)",
        &["n", "DML (1 node, seq)", "DML_Ray (5x8)", "speedup", "tasks", "net GB"],
    );
    for &n in scales {
        let cfg = ccfg(n, d, d_pad);
        let seq_ctx = RayContext::sim(
            ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() },
            false,
        );
        let seq = dml::fit_dry(&seq_ctx, &cost, n, &cfg, 2)?;
        let ray_ctx = RayContext::sim(cluster.clone(), false);
        let ray = dml::fit_dry(&ray_ctx, &cost, n, &cfg, 2)?;
        records.push(record("sim-seq", 1, n, d, &seq));
        records.push(record("sim-ray", cluster.nodes * cluster.slots_per_node, n, d, &ray));
        tbl.row(vec![
            format!("{n}"),
            fmt_secs(seq.makespan),
            fmt_secs(ray.makespan),
            format!("{:.1}x", seq.makespan / ray.makespan),
            format!("{}", ray.tasks_run),
            format!("{:.2}", ray.bytes_transferred as f64 / 1e9),
        ]);
    }
    tbl.print();

    // ---- Part C: skewed-worker sweep (straggler + speculation) -----------
    // One node of the 5x8 cluster runs every task 10x slower; with
    // speculation off the makespan is hostage to that node, with it on
    // clones of the stragglers land on healthy nodes and win the
    // first-result race.  NEXUS_PERF_SMOKE=1 turns the comparison into a
    // hard gate.
    let smoke = std::env::var("NEXUS_PERF_SMOKE").is_ok();
    {
        let n = if quick { 10_000 } else { 100_000 };
        let cfg = ccfg(n, d, d_pad);
        let skew = FaultPlan { node_slow: vec![(1, 10.0)], ..FaultPlan::none() };
        let run = |spec: SpecPolicy| -> nexus::Result<Metrics> {
            let ctx = RayContext::sim_with(
                cluster.clone(),
                false,
                ExecOpts { fault: skew.clone(), spec, ..ExecOpts::default() },
            );
            dml::fit_dry(&ctx, &cost, n, &cfg, 2)
        };
        let off = run(SpecPolicy::off())?;
        let on = run(SpecPolicy::with_factor(2.0))?;
        println!(
            "\n[skew 10x on node 1] {n} x {d} on 5x8: no-spec {} vs spec {} ({:.2}x) | \
             clones {} (wins {}, losses {}) | steals {}",
            fmt_secs(off.makespan),
            fmt_secs(on.makespan),
            off.makespan / on.makespan,
            on.spec_launched,
            on.spec_wins,
            on.spec_losses,
            on.steals,
        );
        records.push(record("sim-skew-nospec", cluster.nodes * cluster.slots_per_node, n, d, &off));
        records.push(record("sim-skew-spec", cluster.nodes * cluster.slots_per_node, n, d, &on));
        if smoke && on.makespan >= off.makespan {
            return Err(nexus::NexusError::Data(format!(
                "perf smoke: speculation did not beat no-speculation under 10x skew \
                 ({} >= {})",
                fmt_secs(on.makespan),
                fmt_secs(off.makespan)
            )));
        }
    }

    // ---- Part D: the shuffle stays off the driver; estimates survive ----
    // Real (executing) runs under injected stragglers with speculation on:
    // the repartition/split_by_fold exchange must move zero block bytes
    // through the driver, and the estimates must stay bit-identical to a
    // clean inline fit on every executor.
    {
        let (sn, sd) = (2_000, 50);
        let sd_pad = 64;
        let ds = generate(&SynthConfig { n: sn, d: sd, seed: 7, ..Default::default() });
        let cfg = ccfg(sn, sd, sd_pad);
        let base = dml::fit_with(&RayContext::inline(), kx.clone(), &cost, &ds, &cfg, 1, 2)?;
        let straggle =
            FaultPlan { node_slow: vec![(1, 10.0)], ..FaultPlan::with_delay(0.1, 0.005, 99) };
        let opts = ExecOpts {
            fault: straggle,
            spec: SpecPolicy::with_factor(3.0),
            ..ExecOpts::default()
        };
        let ctxs = [
            ("straggle-inline", RayContext::inline_with(opts.clone())),
            ("straggle-threads", RayContext::threads_with(3, opts.clone())),
            ("straggle-sim", RayContext::sim_with(cluster.clone(), true, opts)),
        ];
        for (mode, ctx) in ctxs {
            let fit = dml::fit_with(&ctx, kx.clone(), &cost, &ds, &cfg, 1, 2)?;
            let m = ctx.metrics();
            if fit.theta != base.theta || fit.ate.value != base.ate.value {
                return Err(nexus::NexusError::Data(format!(
                    "{mode}: straggler run changed the estimate (ATE {} vs {})",
                    fit.ate.value, base.ate.value
                )));
            }
            if m.driver_block_bytes != 0 {
                return Err(nexus::NexusError::Data(format!(
                    "{mode}: shuffle routed {} block bytes through the driver",
                    m.driver_block_bytes
                )));
            }
            println!(
                "[{mode}] {sn} x {sd}: ATE bit-equal to clean inline | driver block bytes 0 | \
                 shuffle bytes {} | clones {} (wins {})",
                m.shuffle_bytes, m.spec_launched, m.spec_wins
            );
            records.push(record(mode, 3, sn, sd, &m));
        }
    }

    // append this invocation as one session so the trajectory across
    // PRs/invocations accumulates instead of being overwritten
    let path = std::path::Path::new("BENCH_dml_runtime.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    let n_runs = records.len();
    sessions.push(
        Json::obj()
            .set("backend", kx.name())
            .set("quick", quick)
            .set("gflops_effective", blocked_cal.gflops)
            .set("gflops_blocked_scalar", scalar_cal.gflops)
            .set("gflops_naive", naive_cal.gflops)
            .set("kernel_speedup", kernel_speedup)
            .set("simd_dispatch", simd_dispatch.name())
            .set("simd_speedup", simd_speedup)
            .set("gflops_cost_model", cost.gflops)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj()
        .set("bench", "fig6_dml_runtime")
        .set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!("\nwrote BENCH_dml_runtime.json ({n_runs} runs this session, {n_sessions} sessions total)");

    println!(
        "\npaper shape check: DML_Ray << DML at every scale, gap grows with n\n\
         (paper Fig 6 has no numeric axes; the validated content is the ordering + growth)"
    );
    Ok(())
}
