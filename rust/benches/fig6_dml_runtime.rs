//! Figure 6 reproduction: DML vs DML_Ray runtime at 10k / 100k / 1M
//! treated units x ~500 covariates on a 5-node cluster (paper §5.3).
//!
//! Method (DESIGN.md §3, §5): this box has one core, so the cluster is
//! simulated — task costs are CALIBRATED from real PJRT kernel
//! executions on this machine, then the schedule runs under a virtual
//! clock.  Part A validates the simulator: a real sequential run at 10k
//! is compared against the 1-node-1-slot virtual makespan.  Part B
//! regenerates the figure's series at all three scales.
//!
//!     cargo bench --offline --bench fig6_dml_runtime
//!     NEXUS_BENCH_QUICK=1 ... (skips the real 10k x 500 validation run)

use std::time::Instant;

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::dml;
use nexus::config::ClusterConfig;
use nexus::data::synth::{generate, SynthConfig};
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::backend_by_name;

fn ccfg(n: usize, d: usize, d_pad: usize) -> CrossfitConfig {
    CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: if n / 5 > 2048 { 4096 } else { 256 },
        d_pad,
        d_real: d,
        seed: 123,
        stratified: false,
        reuse_suffstats: false,
    }
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let d = 500;
    let d_pad = 512;

    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    println!("backend: {}", kx.name());
    // calibrate the virtual-time cost model from real kernel executions
    let cost = CostModel::calibrate(kx.as_ref(), 256, d_pad);
    println!(
        "calibrated cost model: {:.2} GFLOP/s effective, {:.0}us fixed/task",
        cost.gflops,
        cost.task_fixed * 1e6
    );

    // ---- Part A: simulator validation at 10k x 500 (real vs virtual) ----
    if !quick {
        let n = 10_000;
        let ds = generate(&SynthConfig { n, d, seed: 123, ..Default::default() });
        let cfg = ccfg(n, d, d_pad);
        let t0 = Instant::now();
        let fit = dml::fit_with(&RayContext::inline(), kx.clone(), &cost, &ds, &cfg, 1, 2)?;
        let real_seq = t0.elapsed().as_secs_f64();
        let sim_seq = {
            let ctx = RayContext::sim(
                ClusterConfig { nodes: 1, slots_per_node: 1, ..Default::default() },
                false,
            );
            dml::fit_dry(&ctx, &cost, n, &cfg, 2)?.makespan
        };
        println!(
            "\n[validation] 10k x {d}: real sequential {} vs simulated 1x1 {} (ratio {:.2}) | ATE={:.3}",
            fmt_secs(real_seq),
            fmt_secs(sim_seq),
            real_seq / sim_seq,
            fit.ate.value
        );
    }

    // ---- Part B: the figure ----------------------------------------------
    let cluster = ClusterConfig::default(); // 5 nodes x 8 slots (paper)
    let scales: &[usize] = if quick { &[10_000, 100_000] } else { &[10_000, 100_000, 1_000_000] };

    let mut tbl = Table::new(
        "Figure 6 — DML vs DML_Ray runtime (virtual seconds, calibrated)",
        &["n", "DML (1 node, seq)", "DML_Ray (5x8)", "speedup", "tasks", "net GB"],
    );
    for &n in scales {
        let cfg = ccfg(n, d, d_pad);
        let seq_ctx = RayContext::sim(
            ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() },
            false,
        );
        let seq = dml::fit_dry(&seq_ctx, &cost, n, &cfg, 2)?;
        let ray_ctx = RayContext::sim(cluster.clone(), false);
        let ray = dml::fit_dry(&ray_ctx, &cost, n, &cfg, 2)?;
        tbl.row(vec![
            format!("{n}"),
            fmt_secs(seq.makespan),
            fmt_secs(ray.makespan),
            format!("{:.1}x", seq.makespan / ray.makespan),
            format!("{}", ray.tasks_run),
            format!("{:.2}", ray.bytes_transferred as f64 / 1e9),
        ]);
    }
    tbl.print();
    println!(
        "\npaper shape check: DML_Ray << DML at every scale, gap grows with n\n\
         (paper Fig 6 has no numeric axes; the validated content is the ordering + growth)"
    );
    Ok(())
}
