//! Ablations over our design choices (DESIGN.md §5 "ablations"):
//!
//!   A. task dispatch overhead — the paper's core Ray argument ("lower
//!      task overheads than Spark/joblib"): microseconds per empty task
//!      through the thread-pool scheduler vs inline calls.
//!   B. L1 impl family — pallas(interpret) vs jnp artifacts for the same
//!      gram graph through PJRT: the cost of exercising the TPU-shaped
//!      kernel on a CPU backend.
//!   C. block size — 256 vs 4096 rows/block at fixed work: task-grain
//!      trade-off (dispatch+transfer overhead vs parallelism).
//!   D. network — cluster speedup sensitivity to bandwidth (locality
//!      scheduling keeps the hot path off the wire).
//!
//!     cargo bench --offline --bench ablation_overhead

use std::sync::Arc;

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::dml;
use nexus::config::ClusterConfig;
use nexus::data::matrix::Matrix;
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::RayContext;
use nexus::raylet::payload::Payload;
use nexus::runtime::backend::backend_by_name;
use nexus::util::rng::Pcg32;
use nexus::util::timer::bench_loop;

fn main() -> nexus::Result<()> {
    ablation_a_dispatch_overhead();
    ablation_b_impl_family()?;
    ablation_c_block_size()?;
    ablation_d_network()?;
    ablation_e_suffstat_reuse()?;
    Ok(())
}

fn ablation_e_suffstat_reuse() -> nexus::Result<()> {
    // our optimization beyond the paper: compute each block's Gram once
    // and derive every fold's training stats as (total - fold_sum) —
    // exact for ridge, cuts gram map work by (K-1)/K.  Real wall-clock,
    // sequential executor, 20k x 512.
    use nexus::data::synth::{generate, SynthConfig};
    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    let ds = generate(&SynthConfig { n: 20_000, d: 500, seed: 3, ..Default::default() });
    let cost = CostModel::calibrate(kx.as_ref(), 256, 512);
    let base_cfg = CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: 256,
        d_pad: 512,
        d_real: 500,
        seed: 3,
        stratified: false,
        reuse_suffstats: false,
    };
    let mut tbl = Table::new(
        "E. suffstat reuse (real wall, n=20k x 512, sequential DML)",
        &["mode", "wall", "tasks", "ATE"],
    );
    for reuse in [false, true] {
        let cfg = CrossfitConfig { reuse_suffstats: reuse, ..base_cfg.clone() };
        let ctx = RayContext::inline();
        let start = std::time::Instant::now();
        let fit = dml::fit_with(&ctx, kx.clone(), &cost, &ds, &cfg, 1, 2)?;
        let wall = start.elapsed().as_secs_f64();
        tbl.row(vec![
            if reuse { "reuse (total - fold)" } else { "naive (per-fold grams)" }.into(),
            fmt_secs(wall),
            format!("{}", fit.metrics.tasks_run),
            format!("{:.4}", fit.ate.value),
        ]);
    }
    tbl.print();
    Ok(())
}

fn ablation_a_dispatch_overhead() {
    let n_tasks = 20_000u64;
    let mut tbl = Table::new(
        "A. dispatch overhead (empty tasks)",
        &["executor", "tasks", "wall", "per-task"],
    );
    for workers in [1usize, 2, 4] {
        let ctx = RayContext::threads(workers);
        let start = std::time::Instant::now();
        let refs: Vec<_> = (0..n_tasks)
            .map(|i| {
                ctx.submit(
                    "noop",
                    vec![],
                    0.0,
                    Arc::new(move |_: &[&Payload]| Ok(Payload::Scalar(i as f64))),
                )
            })
            .collect();
        ctx.wait_all(&refs).unwrap();
        let wall = start.elapsed().as_secs_f64();
        tbl.row(vec![
            format!("threads({workers})"),
            format!("{n_tasks}"),
            fmt_secs(wall),
            format!("{:.1}us", wall / n_tasks as f64 * 1e6),
        ]);
    }
    let ctx = RayContext::inline();
    let start = std::time::Instant::now();
    for i in 0..n_tasks {
        let r = ctx.submit(
            "noop",
            vec![],
            0.0,
            Arc::new(move |_: &[&Payload]| Ok(Payload::Scalar(i as f64))),
        );
        std::hint::black_box(r);
    }
    let wall = start.elapsed().as_secs_f64();
    tbl.row(vec![
        "inline (no scheduler)".into(),
        format!("{n_tasks}"),
        fmt_secs(wall),
        format!("{:.1}us", wall / n_tasks as f64 * 1e6),
    ]);
    tbl.print();
    println!("(Ray's reported dispatch overhead is ~100us-1ms/task; ours must stay well under the ~ms-scale kernel costs)");
}

fn ablation_b_impl_family() -> nexus::Result<()> {
    let Ok(jnp) = backend_by_name("pjrt") else {
        println!("\nB. skipped (artifacts not built)");
        return Ok(());
    };
    let pallas = backend_by_name("pjrt-pallas")?;
    let mut rng = Pcg32::new(5);
    let x = Matrix::from_fn(256, 64, |_, _| rng.normal_f32());
    let y: Vec<f32> = (0..256).map(|_| rng.normal_f32()).collect();
    let mask = vec![1.0f32; 256];

    let mut tbl = Table::new(
        "B. L1 impl family: gram_256x64 through PJRT",
        &["impl", "mean", "p95", "note"],
    );
    for (name, kx, note) in [
        ("jnp (native dot)", &jnp, "production hot path"),
        ("pallas (interpret)", &pallas, "TPU-shaped kernel, loop HLO on CPU"),
    ] {
        let stats = bench_loop(3, 30, || kx.gram_block(&x, &y, &mask).unwrap());
        tbl.row(vec![
            name.into(),
            fmt_secs(stats.mean()),
            fmt_secs(stats.p95()),
            note.into(),
        ]);
    }
    tbl.print();
    Ok(())
}

fn ablation_c_block_size() -> nexus::Result<()> {
    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    let cost = CostModel::calibrate(kx.as_ref(), 256, 512);
    let n = 200_000;
    let mut tbl = Table::new(
        "C. block size (n=200k x 512, 5x8 cluster, virtual)",
        &["block", "tasks", "makespan", "overhead", "transfer"],
    );
    for block in [256usize, 4096] {
        let cfg = CrossfitConfig {
            cv: 5,
            lam_y: 1e-3,
            lam_t: 1e-3,
            irls_iters: 5,
            block,
            d_pad: 512,
            d_real: 500,
            seed: 1,
            stratified: false,
            reuse_suffstats: false,
        };
        let ctx = RayContext::sim(ClusterConfig::default(), false);
        let m = dml::fit_dry(&ctx, &cost, n, &cfg, 2)?;
        tbl.row(vec![
            format!("{block}"),
            format!("{}", m.tasks_run),
            fmt_secs(m.makespan),
            fmt_secs(m.overhead_secs),
            fmt_secs(m.transfer_secs),
        ]);
    }
    tbl.print();
    Ok(())
}

fn ablation_d_network() -> nexus::Result<()> {
    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    let cost = CostModel::calibrate(kx.as_ref(), 256, 512);
    let cfg = CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: 4096,
        d_pad: 512,
        d_real: 500,
        seed: 1,
        stratified: false,
        reuse_suffstats: false,
    };
    let mut tbl = Table::new(
        "D. network sensitivity (n=200k, 5x8 cluster)",
        &["bandwidth", "makespan", "transfer", "GB moved"],
    );
    for (label, bw) in [("1 Gbit/s", 0.125e9), ("10 Gbit/s", 1.25e9), ("100 Gbit/s", 12.5e9)] {
        let ctx = RayContext::sim(
            ClusterConfig { net_bandwidth: bw, ..ClusterConfig::default() },
            false,
        );
        let m = dml::fit_dry(&ctx, &cost, 200_000, &cfg, 2)?;
        tbl.row(vec![
            label.into(),
            fmt_secs(m.makespan),
            fmt_secs(m.transfer_secs),
            format!("{:.2}", m.bytes_transferred as f64 / 1e9),
        ]);
    }
    tbl.print();
    println!("(locality scheduling caches blocks per node: bytes moved ~ one broadcast, not per-task)");
    Ok(())
}
