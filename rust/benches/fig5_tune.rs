//! Figure 5 reproduction: distributed hyper-parameter optimization.
//!
//! The paper's Fig 5 is a stock Ray Tune illustration; the reproducible
//! content is the workflow claim — distributed trials + early stopping
//! find the best config in ~max(trial) instead of ~sum(trial).  This
//! bench sweeps a 16-config grid for `model_t` three ways and reports
//! time-to-best (virtual makespan) and total compute.
//!
//!     cargo bench --offline --bench fig5_tune

use std::sync::Arc;

use nexus::bench_support::{fmt_secs, Table};
use nexus::config::ClusterConfig;
use nexus::data::matrix::Matrix;
use nexus::models::cost::CostModel;
use nexus::models::registry::ModelSpec;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::HostBackend;
use nexus::tune::runner::TuneRunner;
use nexus::tune::sched::ShaSchedule;
use nexus::tune::space::{ParamSpec, SearchSpace};
use nexus::util::rng::Pcg32;

fn main() -> nexus::Result<()> {
    let mut rng = Pcg32::new(11);
    let (n, d) = (8000usize, 16usize);
    let make = |n: usize, rng: &mut Pcg32| {
        let x = Matrix::from_fn(n, d, |_, j| if j == 0 { 1.0 } else { rng.normal_f32() });
        let t: Vec<f32> = (0..n)
            .map(|i| {
                let eta = 1.2 * x.get(i, 1) - 0.7 * x.get(i, 2);
                if rng.bernoulli(nexus::data::synth::sigmoid(eta) as f64) { 1.0 } else { 0.0 }
            })
            .collect();
        (x, t)
    };
    let (x_train, t_train) = make(n, &mut rng);
    let (x_val, t_val) = make(n / 4, &mut rng);
    let runner = TuneRunner {
        kx: Arc::new(HostBackend),
        cost: CostModel::default(),
        x_train,
        target_train: t_train,
        x_val,
        target_val: t_val,
        to_spec: |c| ModelSpec::Logistic { lam: c.get("lam") as f32, iters: c.get_usize("iters") },
        block: 256,
    };
    let configs = SearchSpace::new()
        .with("lam", ParamSpec::Grid(vec![1e-5, 1e-3, 1e-1, 10.0]))
        .with("iters", ParamSpec::Grid(vec![2.0, 4.0, 6.0, 8.0]))
        .grid(0);
    let cluster = ClusterConfig { nodes: 4, slots_per_node: 4, ..Default::default() };
    let sched = ShaSchedule::geometric(1, 4, 2);

    let mut tbl = Table::new(
        "Figure 5 — tuning strategies (16-config grid, model_t)",
        &["strategy", "time-to-best", "total cpu", "tasks", "best loss"],
    );
    let serial = runner.run_grid(
        &RayContext::sim(ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() }, true),
        &configs,
    )?;
    let dist = runner.run_grid(&RayContext::sim(cluster.clone(), true), &configs)?;
    let sha = runner.run_sha(&RayContext::sim(cluster.clone(), true), &configs, &sched)?;
    for (name, o) in [("serial grid", &serial), ("distributed grid", &dist), ("dist + SHA", &sha)]
    {
        tbl.row(vec![
            name.into(),
            fmt_secs(o.makespan),
            fmt_secs(o.busy_secs),
            format!("{}", o.tasks_run),
            format!("{:.4}", o.best.loss),
        ]);
    }
    tbl.print();
    println!(
        "\nspeedups vs serial: distributed {:.1}x, dist+SHA {:.1}x (time-to-best)",
        serial.makespan / dist.makespan,
        serial.makespan / sha.makespan
    );
    assert_eq!(serial.best.config, dist.best.config, "winners must agree");
    Ok(())
}
