//! Figure 5 reproduction: distributed hyper-parameter optimization.
//!
//! The paper's Fig 5 is a stock Ray Tune illustration; the reproducible
//! content is the workflow claim — distributed trials + early stopping
//! find the best config in ~max(trial) instead of ~sum(trial).  This
//! bench sweeps a 16-config logistic grid for `model_t` across the
//! scheduler policies (serial grid, distributed grid, synchronous SHA,
//! actor-based ASHA with and without the median rule / injected kills)
//! and reports time-to-best (virtual makespan), total compute, and the
//! checkpoint/kill counters.
//!
//! Every run is appended to `BENCH_fig5_tune.json` (machine-readable;
//! schema in EXPERIMENTS.md): one record per trials x workers x policy
//! combination.
//!
//!     cargo bench --offline --bench fig5_tune
//!     NEXUS_BENCH_QUICK=1 ... (smaller sweep for CI)
//!     NEXUS_PERF_SMOKE=1  ... (fail unless ASHA beats the distributed grid)

use std::sync::Arc;

use nexus::bench_support::{fmt_secs, Table};
use nexus::config::ClusterConfig;
use nexus::data::matrix::Matrix;
use nexus::models::cost::CostModel;
use nexus::models::registry::ModelSpec;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::HostBackend;
use nexus::tune::runner::{AshaOpts, TuneOutcome, TuneRunner};
use nexus::tune::sched::ShaSchedule;
use nexus::tune::space::{ParamSpec, SearchSpace, TrialConfig};
use nexus::util::json::Json;
use nexus::util::rng::Pcg32;

fn record(policy: &str, trials: usize, workers: usize, o: &TuneOutcome) -> Json {
    Json::obj()
        .set("policy", policy)
        .set("trials", trials)
        .set("workers", workers)
        .set("time_to_best_secs", o.time_to_best)
        .set("makespan_secs", o.makespan)
        .set("busy_secs", o.busy_secs)
        .set("tasks", o.tasks_run as i64)
        .set("rows_trained", o.rows_trained as i64)
        .set("killed", o.killed as i64)
        .set("resumed", o.resumed as i64)
        .set("best_loss", o.best.loss)
        .set("best_lam", o.best.config.get("lam"))
        .set("best_iters", o.best.config.get_usize("iters"))
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let smoke = std::env::var("NEXUS_PERF_SMOKE").is_ok();
    let mut rng = Pcg32::new(11);
    let (n, d) = (if quick { 4000usize } else { 8000 }, 16usize);
    let make = |n: usize, rng: &mut Pcg32| {
        let x = Matrix::from_fn(n, d, |_, j| if j == 0 { 1.0 } else { rng.normal_f32() });
        let t: Vec<f32> = (0..n)
            .map(|i| {
                let eta = 1.2 * x.get(i, 1) - 0.7 * x.get(i, 2);
                if rng.bernoulli(nexus::data::synth::sigmoid(eta) as f64) { 1.0 } else { 0.0 }
            })
            .collect();
        (x, t)
    };
    let (x_train, t_train) = make(n, &mut rng);
    let (x_val, t_val) = make(n / 4, &mut rng);
    let runner = TuneRunner {
        kx: Arc::new(HostBackend),
        cost: CostModel::default(),
        x_train,
        target_train: t_train,
        x_val,
        target_val: t_val,
        to_spec: |c| ModelSpec::Logistic { lam: c.get("lam") as f32, iters: c.get_usize("iters") },
        block: 256,
    };
    let configs: Vec<TrialConfig> = SearchSpace::new()
        .with("lam", ParamSpec::Grid(vec![1e-5, 1e-3, 1e-1, 10.0]))
        .with("iters", ParamSpec::Grid(vec![2.0, 4.0, 6.0, 8.0]))
        .grid(0);
    let trials = configs.len();
    let workers = 16usize; // 4 nodes x 4 slots
    let cluster = ClusterConfig { nodes: 4, slots_per_node: 4, ..Default::default() };
    let sched = ShaSchedule::geometric(1, 4, 2)?;
    let asha_opts = |median_stop: bool, kill_at: Vec<(usize, usize)>| AshaOpts {
        workers,
        median_stop,
        kill_at,
        ..AshaOpts::default()
    };

    let serial = runner.run_grid(
        &RayContext::sim(ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() }, true),
        &configs,
    )?;
    let dist = runner.run_grid(&RayContext::sim(cluster.clone(), true), &configs)?;
    let sha = runner.run_sha(&RayContext::sim(cluster.clone(), true), &configs, &sched)?;
    let asha =
        runner.run_asha(&RayContext::inline(), &configs, &sched, &asha_opts(false, vec![]))?;
    let median =
        runner.run_asha(&RayContext::inline(), &configs, &sched, &asha_opts(true, vec![]))?;
    // kill the eventual winner as its mid-ladder rungs dispatch: it must
    // resume from its object-store checkpoint instead of retraining rung 0
    let winner = configs.iter().position(|c| *c == asha.best.config).unwrap();
    let kills = runner.run_asha(
        &RayContext::inline(),
        &configs,
        &sched,
        &asha_opts(false, vec![(winner, 1), (winner, 2)]),
    )?;

    // the workers dimension: a narrower ASHA sweep for the same trials
    let asha_w4 = if quick {
        None
    } else {
        Some(runner.run_asha(
            &RayContext::inline(),
            &configs,
            &sched,
            &AshaOpts { workers: 4, ..AshaOpts::default() },
        )?)
    };

    let mut rows: Vec<(&str, usize, &TuneOutcome)> = vec![
        ("grid-serial", 1, &serial),
        ("grid-dist", workers, &dist),
        ("sha-sync", workers, &sha),
        ("asha", workers, &asha),
        ("asha-median", workers, &median),
        ("asha-kills", workers, &kills),
    ];
    if let Some(o) = &asha_w4 {
        rows.push(("asha", 4, o));
    }

    let mut tbl = Table::new(
        "Figure 5 — tuning policies (16-config logistic grid, model_t)",
        &["policy", "workers", "time-to-best", "total cpu", "tasks", "rows", "killed", "best loss"],
    );
    let mut records: Vec<Json> = Vec::new();
    for &(name, w, o) in &rows {
        tbl.row(vec![
            name.into(),
            format!("{w}"),
            fmt_secs(o.time_to_best),
            fmt_secs(o.busy_secs),
            format!("{}", o.tasks_run),
            format!("{}", o.rows_trained),
            format!("{}", o.killed),
            format!("{:.4}", o.best.loss),
        ]);
        records.push(record(name, trials, w, o));
    }
    tbl.print();
    println!(
        "\nspeedups vs serial grid (time-to-best): dist {:.1}x, sync SHA {:.1}x, ASHA {:.1}x",
        serial.makespan / dist.makespan,
        serial.makespan / sha.makespan,
        serial.makespan / asha.time_to_best
    );
    println!(
        "asha checkpoints under kills: killed={} resumed={} (winner loss {:.4})",
        kills.killed, kills.resumed, kills.best.loss
    );

    assert_eq!(serial.best.config, dist.best.config, "grid winners must agree");
    assert!(asha.best.budget >= sha.best.budget, "asha winner must train at full budget");
    assert!(
        asha.time_to_best < sha.makespan,
        "asha time-to-best {} must beat synchronous SHA makespan {}",
        asha.time_to_best,
        sha.makespan
    );
    assert!(kills.resumed > 0, "injected kills must exercise checkpoint resume");
    if smoke {
        assert!(
            asha.time_to_best < dist.makespan,
            "perf smoke: asha time-to-best {} must beat distributed grid {}",
            asha.time_to_best,
            dist.makespan
        );
    }

    // append this invocation as one session so the trajectory across
    // PRs/invocations accumulates instead of being overwritten
    let path = std::path::Path::new("BENCH_fig5_tune.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    let n_runs = records.len();
    sessions.push(
        Json::obj()
            .set("backend", "host")
            .set("quick", quick)
            .set("n", n)
            .set("d", d)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj().set("bench", "fig5_tune").set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!(
        "\nwrote BENCH_fig5_tune.json ({n_runs} runs this session, {n_sessions} sessions total)"
    );
    Ok(())
}
