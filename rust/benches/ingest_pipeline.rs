//! Ingest-pipeline bench: driver peak bytes of streaming sharded ingest
//! vs the materialized path, swept over rows × chunk size.
//!
//! The sharded dataset plane exists to bound the driver's data footprint
//! by O(chunk) instead of O(n·d) (DESIGN.md §7).  This bench produces
//! the evidence: for each (n, d) × chunk it streams the synthetic table
//! into the object store, records the ingest report's driver peak, and
//! compares against what materialized residence would hold.  A DML
//! equality check (streaming vs materialized fit on the same seed, bit
//! compared) guards the numbers' meaning: the memory win is only real if
//! the estimates are unchanged.
//!
//! Every run is appended to `BENCH_ingest_pipeline.json`
//! (EXPERIMENTS.md documents the schema).
//!
//!     cargo bench --offline --bench ingest_pipeline
//!     NEXUS_BENCH_QUICK=1 ...  (tiny sweep for CI)

use std::time::Instant;

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::dml;
use nexus::data::dataset::{IngestOpts, ShardedDataset};
use nexus::data::synth::{generate, SynthConfig};
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::{backend_by_name, KernelExec};
use nexus::util::json::Json;
use std::sync::Arc;

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let mut records: Vec<Json> = Vec::new();

    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    println!("backend: {}", kx.name());

    // ---- Part A: driver-peak sweep (rows x chunk) ------------------------
    let scales: &[(usize, usize)] = if quick {
        &[(2_000, 16), (8_000, 16)]
    } else {
        &[(10_000, 64), (100_000, 64), (1_000_000, 64)]
    };
    let chunks: &[usize] = if quick { &[512, 2048] } else { &[4096, 65_536] };
    let block = if quick { 256 } else { 4096 };

    let mut tbl = Table::new(
        "Streaming ingest — driver peak bytes vs materialized (O(chunk) vs O(n))",
        &["n", "d", "chunk", "blocks", "driver peak", "materialized", "ratio", "ingest"],
    );
    for &(n, d) in scales {
        let d_pad = (d + 1).next_power_of_two().max(16);
        for &chunk in chunks {
            let cfg = SynthConfig { n, d, seed: 123, ..Default::default() };
            let ctx = RayContext::inline();
            let t0 = Instant::now();
            let (sds, report) =
                ShardedDataset::ingest_synth(&ctx, &cfg, d_pad, &IngestOpts { chunk, block })?;
            let wall = t0.elapsed().as_secs_f64();
            // what the driver holds on the materialized path: raw matrix,
            // padded copy, and the four per-row columns
            let materialized = 4 * n * (d + d_pad + 4);
            let ratio = materialized as f64 / report.driver_peak_bytes.max(1) as f64;
            tbl.row(vec![
                format!("{n}"),
                format!("{d}"),
                format!("{}", report.chunk_rows),
                format!("{}", sds.n_blocks()),
                format!("{}", report.driver_peak_bytes),
                format!("{materialized}"),
                format!("{ratio:.1}x"),
                fmt_secs(wall),
            ]);
            records.push(
                Json::obj()
                    .set("kind", "ingest")
                    .set("n", n)
                    .set("d", d)
                    .set("d_pad", d_pad)
                    .set("chunk_rows", report.chunk_rows)
                    .set("block", block)
                    .set("blocks", report.blocks)
                    .set("driver_peak_bytes", report.driver_peak_bytes)
                    .set("materialized_bytes", materialized)
                    .set("store_bytes", report.store_bytes)
                    .set("ratio", ratio)
                    .set("ingest_secs", wall),
            );
        }
    }
    tbl.print();

    // ---- Part B: estimates must be unchanged -----------------------------
    // streaming vs materialized DML on the same seed, bit-compared — the
    // memory numbers above only count if this holds.
    let (cn, cd) = if quick { (2_000, 4) } else { (6_000, 6) };
    let ccfg = CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: 256,
        d_pad: (cd + 1).next_power_of_two().max(16),
        d_real: cd,
        seed: 123,
        stratified: true,
        reuse_suffstats: false,
    };
    let scfg = SynthConfig { n: cn, d: cd, seed: 123, ..Default::default() };
    let cost = CostModel::default();
    // host backend: the equality check uses shapes outside the shipped
    // artifact catalog, which only the host oracle accepts everywhere
    let host: Arc<dyn KernelExec> = backend_by_name("host")?;
    let ds = generate(&scfg);
    let mat = dml::fit_with(&RayContext::inline(), host.clone(), &cost, &ds, &ccfg, 1, 2)?;
    let ctx = RayContext::inline();
    let (sds, report) = ShardedDataset::ingest_synth(
        &ctx,
        &scfg,
        ccfg.d_pad,
        &IngestOpts { chunk: 1024, block: 256 },
    )?;
    let st = dml::fit_sharded(&ctx, host, &cost, &sds, &ccfg, 1, 2)?;
    let identical = mat.theta == st.theta && mat.ate.value == st.ate.value;
    println!(
        "\n[equality] n={cn} d={cd}: streaming theta == materialized theta: {identical} \
         (ATE {:.4} vs {:.4}; streaming driver peak {} B)",
        st.ate.value, mat.ate.value, report.driver_peak_bytes
    );
    assert!(identical, "streaming ingest changed the estimates — the bench numbers are void");
    records.push(
        Json::obj()
            .set("kind", "dml_equality")
            .set("n", cn)
            .set("d", cd)
            .set("identical", identical)
            .set("ate", st.ate.value)
            .set("driver_peak_bytes", report.driver_peak_bytes),
    );

    // append this invocation as one session (same pattern as fig6)
    let path = std::path::Path::new("BENCH_ingest_pipeline.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    let n_runs = records.len();
    sessions.push(
        Json::obj()
            .set("backend", kx.name())
            .set("quick", quick)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj()
        .set("bench", "ingest_pipeline")
        .set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!("\nwrote BENCH_ingest_pipeline.json ({n_runs} runs this session, {n_sessions} sessions total)");
    Ok(())
}
