//! Serving-plane latency sweep: arrival rate x replica count x routing
//! policy through the multi-replica router (DESIGN.md "Serving plane").
//!
//! An open-loop load generator fires requests with deterministic
//! exponential inter-arrivals (seeded PCG32, so the arrival process is
//! identical across configurations) and never blocks on the plane —
//! exactly the regime where queueing delay, batching, and routing
//! policy separate.  Per configuration we report throughput, batch
//! shape, and end-to-end latency p50/p95/p99.
//!
//! Every run is appended to `BENCH_serve_latency.json` (machine-readable;
//! schema in EXPERIMENTS.md) so the serving-latency trajectory is
//! tracked across PRs alongside `BENCH_dml_runtime.json`.
//!
//!     cargo bench --offline --bench serve_latency
//!     NEXUS_BENCH_QUICK=1 ... (smaller sweep for CI)

use std::time::Duration;

use nexus::bench_support::Table;
use nexus::runtime::backend::HostBackend;
use nexus::serve::{BatchPolicy, CateModel, Router, RoutingPolicy};
use nexus::util::json::Json;
use nexus::util::rng::Pcg32;

struct RunResult {
    wall: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    batches: u64,
    rerouted: u64,
}

/// One open-loop run: `requests` arrivals at `rate`/sec (0 = closed
/// loop) against `replicas` replicas under `routing`.
fn run_once(
    routing: RoutingPolicy,
    replicas: usize,
    rate: f64,
    requests: usize,
) -> nexus::Result<RunResult> {
    let model = CateModel { theta: vec![1.0, 0.5], het: 1, block: 256, d_pad: 16 };
    let policy = BatchPolicy { max_batch: 64, max_delay: Duration::from_millis(1) };
    let mut router =
        Router::new(model, std::sync::Arc::new(HostBackend), policy, routing, replicas)?;
    let mut rng = Pcg32::new(42);
    let wall = router.run_open_loop(requests, rate, &mut rng, |rng| vec![rng.normal_f32()])?;
    assert_eq!(router.completed.len(), requests, "serving plane lost requests");
    let s = router.stats();
    Ok(RunResult {
        wall,
        p50_ms: s.latency.p50() * 1e3,
        p95_ms: s.latency.p95() * 1e3,
        p99_ms: s.latency.p99() * 1e3,
        mean_batch: s.mean_batch_size(),
        batches: s.batches,
        rerouted: s.rerouted,
    })
}

fn record(
    routing: RoutingPolicy,
    replicas: usize,
    rate: f64,
    requests: usize,
    r: &RunResult,
) -> Json {
    Json::obj()
        .set("policy", routing.name())
        .set("replicas", replicas)
        .set("rate", rate)
        .set("requests", requests)
        .set("wall_secs", r.wall)
        .set("throughput_rps", requests as f64 / r.wall)
        .set("latency_p50_ms", r.p50_ms)
        .set("latency_p95_ms", r.p95_ms)
        .set("latency_p99_ms", r.p99_ms)
        .set("mean_batch_size", r.mean_batch)
        .set("batches", r.batches as i64)
        .set("rerouted", r.rerouted as i64)
}

fn main() -> nexus::Result<()> {
    let quick = std::env::var("NEXUS_BENCH_QUICK").is_ok();
    let policies =
        [RoutingPolicy::RoundRobin, RoutingPolicy::LeastOutstanding, RoutingPolicy::PowerOfTwo];
    let replica_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let rates: &[f64] = if quick { &[2000.0] } else { &[1000.0, 4000.0] };
    let requests: usize = if quick { 1_000 } else { 4_000 };

    let mut records: Vec<Json> = Vec::new();
    let mut tbl = Table::new(
        "Serving-plane latency sweep (open loop, host backend)",
        &["policy", "replicas", "rate/s", "p50 ms", "p95 ms", "p99 ms", "mean batch", "req/s"],
    );
    for &rate in rates {
        for &replicas in replica_counts {
            for routing in policies {
                let r = run_once(routing, replicas, rate, requests)?;
                tbl.row(vec![
                    routing.name().to_string(),
                    format!("{replicas}"),
                    format!("{rate:.0}"),
                    format!("{:.3}", r.p50_ms),
                    format!("{:.3}", r.p95_ms),
                    format!("{:.3}", r.p99_ms),
                    format!("{:.1}", r.mean_batch),
                    format!("{:.0}", requests as f64 / r.wall),
                ]);
                records.push(record(routing, replicas, rate, requests, &r));
            }
        }
    }
    tbl.print();

    // append this invocation as one session so the trajectory across
    // PRs/invocations accumulates instead of being overwritten
    let path = std::path::Path::new("BENCH_serve_latency.json");
    let mut sessions: Vec<Json> = nexus::util::json::parse_file(path)
        .ok()
        .and_then(|old| old.get("sessions").and_then(|s| s.as_arr().ok().map(|a| a.to_vec())))
        .unwrap_or_default();
    let n_runs = records.len();
    sessions.push(
        Json::obj()
            .set("backend", "host")
            .set("quick", quick)
            .set("runs", Json::Arr(records)),
    );
    let n_sessions = sessions.len();
    let out = Json::obj()
        .set("bench", "serve_latency")
        .set("sessions", Json::Arr(sessions));
    std::fs::write(path, out.to_string())?;
    println!(
        "\nwrote BENCH_serve_latency.json ({n_runs} runs this session, {n_sessions} sessions total)"
    );
    println!(
        "\nshape check: p99 falls as replicas rise at fixed rate; lor/p2c beat rr\n\
         on tail latency under load (absolute ms are machine-dependent)"
    );
    Ok(())
}
