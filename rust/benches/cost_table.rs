//! Cost-optimization table (paper §1 objective "Cost optimizations",
//! §4 autoscaling via Ray Serve/Darwin): what does one DML estimation
//! run cost under three provisioning strategies?
//!
//!   1-node fixed     cheap/slow sequential baseline
//!   5-node fixed     the paper's cluster, billed for the whole run
//!   autoscaled       target-utilization policy over the real schedule
//!
//!     cargo bench --offline --bench cost_table

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::dml;
use nexus::cluster::autoscaler::{self, AutoscalePolicy};
use nexus::cluster::cost::fixed_cluster_cost;
use nexus::config::ClusterConfig;
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::backend_by_name;

fn main() -> nexus::Result<()> {
    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    let cost = CostModel::calibrate(kx.as_ref(), 256, 512);
    let cluster = ClusterConfig::default(); // r5.4xlarge-ish $/h
    let price = cluster.dollars_per_node_hour;

    let mut tbl = Table::new(
        "Cost table — one DML run (d=500, cv=5), $ at r5.4xlarge on-demand",
        &["n", "strategy", "makespan", "node-hours", "$", "util"],
    );
    for n in [10_000usize, 100_000, 1_000_000] {
        let cfg = CrossfitConfig {
            cv: 5,
            lam_y: 1e-3,
            lam_t: 1e-3,
            irls_iters: 5,
            block: if n / 5 > 2048 { 4096 } else { 256 },
            d_pad: 512,
            d_real: 500,
            seed: 1,
            stratified: false,
            reuse_suffstats: false,
        };
        // 1-node fixed
        let seq_ctx = RayContext::sim(
            ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() },
            false,
        );
        let seq = dml::fit_dry(&seq_ctx, &cost, n, &cfg, 2)?;
        let seq_cost = fixed_cluster_cost(seq.makespan, 1, price, seq.busy_secs, 1);
        tbl.row(vec![
            format!("{n}"),
            "1-node fixed".into(),
            fmt_secs(seq.makespan),
            format!("{:.4}", seq_cost.node_hours),
            format!("{:.4}", seq_cost.dollars),
            format!("{:.0}%", seq_cost.utilization * 100.0),
        ]);
        // 5-node fixed
        let ray_ctx = RayContext::sim(cluster.clone(), false);
        let ray = dml::fit_dry(&ray_ctx, &cost, n, &cfg, 2)?;
        let ray_cost = fixed_cluster_cost(
            ray.makespan,
            cluster.nodes,
            price,
            ray.busy_secs,
            cluster.slots_per_node,
        );
        tbl.row(vec![
            format!("{n}"),
            "5-node fixed".into(),
            fmt_secs(ray.makespan),
            format!("{:.4}", ray_cost.node_hours),
            format!("{:.4}", ray_cost.dollars),
            format!("{:.0}%", ray_cost.utilization * 100.0),
        ]);
        // autoscaled over the recorded schedule
        let auto = autoscaler::replay(
            &ray_ctx.gantt(),
            &AutoscalePolicy {
                min_nodes: 1,
                max_nodes: cluster.nodes,
                slots_per_node: cluster.slots_per_node,
                idle_timeout: 5.0,
                boot_time: 10.0,
            },
            price,
        );
        tbl.row(vec![
            format!("{n}"),
            "autoscaled".into(),
            fmt_secs(ray.makespan),
            format!("{:.4}", auto.node_hours),
            format!("{:.4}", auto.dollars_at),
            format!("peak {}", auto.peak_nodes),
        ]);
    }
    tbl.print();
    println!(
        "\nclaims: 5-node fixed trades $ for wall-clock; autoscaling recovers\n\
         most of the idle cost whenever the DAG has serial phases."
    );
    Ok(())
}
