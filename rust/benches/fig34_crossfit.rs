//! Figures 3 & 4 reproduction: sequential vs parallel cross-validation
//! schedule.  The paper shows the K folds running one-after-another
//! (Fig 3) vs simultaneously as Ray tasks (Fig 4).  This bench builds
//! the actual cross-fitting DAG at n=50k x 64 and renders both
//! schedules (virtual time, calibrated costs) plus a fold-level gantt.
//!
//!     cargo bench --offline --bench fig34_crossfit

use nexus::bench_support::{fmt_secs, Table};
use nexus::config::ClusterConfig;
use nexus::models::cost::CostModel;
use nexus::models::crossfit::{self, CrossfitConfig};
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::backend_by_name;

fn main() -> nexus::Result<()> {
    let n = 50_000;
    let cfg = CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: 4096,
        d_pad: 64,
        d_real: 50,
        seed: 3,
        stratified: false,
        reuse_suffstats: false,
    };
    let kx = backend_by_name("pjrt").or_else(|_| backend_by_name("host"))?;
    // calibrate at the bench's own block shape (large enough that the
    // fixed per-task cost doesn't swamp the FLOP measurement)
    let cost = CostModel::calibrate(kx.as_ref(), 4096, 64);
    println!(
        "crossfit DAG: n={n}, d=50, cv=5, block=4096 ({:.2} GFLOP/s calibrated)",
        cost.gflops
    );

    let mut tbl = Table::new(
        "Fig 3 vs Fig 4 — cross-validation schedule",
        &["schedule", "makespan", "busy", "utilization", "tasks"],
    );
    let mut gantts = Vec::new();
    for (name, cluster) in [
        ("sequential (Fig 3)", ClusterConfig { nodes: 1, slots_per_node: 1, ..Default::default() }),
        ("parallel Ray tasks (Fig 4)", ClusterConfig::default()),
    ] {
        let ctx = RayContext::sim(cluster.clone(), false);
        crossfit::run_dry(&ctx, &cost, n, &cfg)?;
        let m = ctx.metrics();
        let slots = (cluster.nodes * cluster.slots_per_node) as f64;
        tbl.row(vec![
            name.into(),
            fmt_secs(m.makespan),
            fmt_secs(m.busy_secs),
            format!("{:.0}%", 100.0 * m.busy_secs / (m.makespan * slots)),
            format!("{}", m.tasks_run),
        ]);
        gantts.push((name, ctx.gantt(), m.makespan));
    }
    tbl.print();

    // fold-level gantt of the parallel schedule: when did each fold's
    // nuisance fits run?
    let (_, gantt, makespan) = &gantts[1];
    println!("\nparallel schedule, fold activity windows (virtual time):");
    for fold in 0..5 {
        let tag = format!("f{fold}:");
        let (mut start, mut end) = (f64::INFINITY, 0.0f64);
        for g in gantt.iter().filter(|g| g.label.starts_with(&tag)) {
            start = start.min(g.start);
            end = end.max(g.end);
        }
        let width = 60.0;
        let s = (start / makespan * width) as usize;
        let e = ((end / makespan * width) as usize).max(s + 1);
        println!(
            "  fold {fold}: [{}{}{}] {} – {}",
            " ".repeat(s),
            "#".repeat(e - s),
            " ".repeat(60usize.saturating_sub(e)),
            fmt_secs(start),
            fmt_secs(end)
        );
    }
    println!("\nFig 4's claim: fold windows OVERLAP (vs strictly serial in Fig 3).");
    Ok(())
}
