//! Distributed hyper-parameter tuning (paper §5.2 / Figure 5 workflow):
//! the same grid swept three ways —
//!
//!   serial        every config, one at a time (sklearn GridSearchCV)
//!   distributed   every config as a parallel trial (Ray Tune grid)
//!   dist + SHA    successive halving: cheap low-budget rungs first
//!
//!     cargo run --release --offline --example tune_sweep

use std::sync::Arc;

use nexus::bench_support::{fmt_secs, Table};
use nexus::config::ClusterConfig;
use nexus::data::matrix::Matrix;
use nexus::models::cost::CostModel;
use nexus::models::registry::ModelSpec;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::HostBackend;
use nexus::tune::runner::TuneRunner;
use nexus::tune::sched::ShaSchedule;
use nexus::tune::space::{ParamSpec, SearchSpace};
use nexus::util::rng::Pcg32;

fn main() -> nexus::Result<()> {
    // tuning problem: pick ridge lam + logistic iters for the propensity
    let mut rng = Pcg32::new(11);
    let (n, d) = (8000usize, 16usize);
    let make = |n: usize, rng: &mut Pcg32| {
        let x = Matrix::from_fn(n, d, |_, j| if j == 0 { 1.0 } else { rng.normal_f32() });
        let t: Vec<f32> = (0..n)
            .map(|i| {
                let eta = 1.2 * x.get(i, 1) - 0.7 * x.get(i, 2);
                if rng.bernoulli(nexus::data::synth::sigmoid(eta) as f64) {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        (x, t)
    };
    let (x_train, t_train) = make(n, &mut rng);
    let (x_val, t_val) = make(n / 4, &mut rng);

    let runner = TuneRunner {
        kx: Arc::new(HostBackend),
        cost: CostModel::default(),
        x_train,
        target_train: t_train,
        x_val,
        target_val: t_val,
        to_spec: |c| ModelSpec::Logistic {
            lam: c.get("lam") as f32,
            iters: c.get_usize("iters"),
        },
        block: 256,
    };

    let space = SearchSpace::new()
        .with("lam", ParamSpec::Grid(vec![1e-5, 1e-3, 1e-1, 10.0]))
        .with("iters", ParamSpec::Grid(vec![2.0, 4.0, 6.0, 8.0]));
    let configs = space.grid(0); // 16 configs
    println!("sweeping {} configs (model_t: logistic lam x iters)\n", configs.len());

    let cluster = ClusterConfig { nodes: 4, slots_per_node: 4, ..Default::default() };
    let sched = ShaSchedule::geometric(1, 4, 2);

    let mut tbl = Table::new(
        "Figure 5 workflow — tuning strategies",
        &["strategy", "best config", "val loss", "cpu-time", "makespan", "tasks"],
    );

    // serial grid (virtual-time so the rows are comparable)
    let serial_ctx = RayContext::sim(
        ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() },
        true,
    );
    let serial = runner.run_grid(&serial_ctx, &configs)?;
    tbl.row(vec![
        "serial grid".into(),
        serial.best.config.describe(),
        format!("{:.4}", serial.best.loss),
        fmt_secs(serial.busy_secs),
        fmt_secs(serial.makespan),
        format!("{}", serial.tasks_run),
    ]);

    // distributed grid
    let dist_ctx = RayContext::sim(cluster.clone(), true);
    let dist = runner.run_grid(&dist_ctx, &configs)?;
    tbl.row(vec![
        "distributed grid".into(),
        dist.best.config.describe(),
        format!("{:.4}", dist.best.loss),
        fmt_secs(dist.busy_secs),
        fmt_secs(dist.makespan),
        format!("{}", dist.tasks_run),
    ]);

    // distributed + successive halving
    let sha_ctx = RayContext::sim(cluster.clone(), true);
    let sha = runner.run_sha(&sha_ctx, &configs, &sched)?;
    tbl.row(vec![
        "distributed + SHA".into(),
        sha.best.config.describe(),
        format!("{:.4}", sha.best.loss),
        fmt_secs(sha.busy_secs),
        fmt_secs(sha.makespan),
        format!("{}", sha.tasks_run),
    ]);
    tbl.print();

    println!(
        "\nspeedup (makespan): distributed {:.1}x, dist+SHA {:.1}x vs serial",
        serial.makespan / dist.makespan,
        serial.makespan / sha.makespan
    );
    println!(
        "cpu-time saved by SHA: {:.1}% of the full grid",
        100.0 * (1.0 - sha.busy_secs / dist.busy_secs)
    );

    // sanity: distributed answers match serial exactly
    assert_eq!(serial.best.config, dist.best.config);
    println!("\ninvariant checked: serial and distributed grids found the same winner");
    Ok(())
}
