//! HEADLINE END-TO-END DRIVER — the full NEXUS workflow (paper §4,
//! Figure 2) on a real workload, proving all three layers compose:
//!
//!   1. synthetic industrial dataset (100k x 50, paper §5.1 DGP)
//!   2. diagnostics (overlap, balance)
//!   3. distributed cross-fit LinearDML through the AOT-compiled XLA
//!      kernels (pallas-authored, PJRT-executed; python not running)
//!   4. estimate vs ground truth + comparison estimators (S/T/X, AIPW)
//!   5. refutation suite (placebo / random-cause / subset)
//!   6. model deployment: batched CATE serving
//!   7. cluster economics: simulated 5-node makespan + cost report
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --offline --example nexus_end_to_end
//!     NEXUS_E2E_N=100000 ... (default 100000; set lower for smoke)

use std::sync::Arc;
use std::time::Instant;

use nexus::bench_support::{fmt_secs, Table};
use nexus::causal::{diagnostics, dml, dr, metalearners, refute};
use nexus::cluster::autoscaler::{self, AutoscalePolicy};
use nexus::config::ClusterConfig;
use nexus::data::synth::{generate, CausalDataset, SynthConfig};
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::{backend_by_name, KernelExec};
use nexus::serve::{BatchPolicy, CateModel, Router, RoutingPolicy};
use nexus::util::rng::Pcg32;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> nexus::Result<()> {
    let n = env_usize("NEXUS_E2E_N", 100_000);
    let d = env_usize("NEXUS_E2E_D", 50);
    let workers = env_usize("NEXUS_E2E_WORKERS", 4);

    println!("=== NEXUS end-to-end: n={n} d={d} ===\n");

    // ---- 1. data -------------------------------------------------------
    let t0 = Instant::now();
    let ds = generate(&SynthConfig { n, d, seed: 123, ..Default::default() });
    println!(
        "[1] generated {}x{} ({} treated, true ATE {:.4}) in {}",
        n,
        d,
        (ds.treated_share() * n as f64) as usize,
        ds.true_ate(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );

    // ---- 2. diagnostics -------------------------------------------------
    let ov = diagnostics::overlap(&ds.true_propensity, &ds.t, 0.01);
    println!(
        "[2] overlap: propensity in [{:.3}, {:.3}], violations {:.2}% => {}",
        ov.min_propensity,
        ov.max_propensity,
        ov.violation_share * 100.0,
        if ov.ok { "OK" } else { "VIOLATED" }
    );
    let bal = diagnostics::balance(&ds, &ds.true_propensity);
    println!(
        "    balance: raw max|SMD| {:.3} -> IPW-weighted {:.3} => {}",
        bal.smd_raw.iter().map(|s| s.abs()).fold(0.0, f64::max),
        bal.max_weighted,
        if bal.ok { "OK" } else { "IMBALANCED" }
    );

    // ---- 3. distributed DML through the PJRT artifacts ------------------
    let kx = backend_by_name("pjrt").unwrap_or_else(|_| {
        println!("    (artifacts missing; falling back to host backend)");
        backend_by_name("host").unwrap()
    });
    let d_pad = if d + 1 <= 64 { 64 } else { 512 };
    let block = if n / 5 > 2048 { 4096 } else { 256 };
    let ccfg = CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block,
        d_pad,
        d_real: d,
        seed: 7,
        stratified: true,
        reuse_suffstats: false,
    };
    let cost = CostModel::calibrate(kx.as_ref(), 256, d_pad.min(64));
    let t1 = Instant::now();
    let ctx = RayContext::threads(workers);
    let fit = dml::fit_with(&ctx, kx.clone(), &cost, &ds, &ccfg, 1, 2)?;
    let dml_wall = t1.elapsed().as_secs_f64();
    println!(
        "[3] DML_Ray (threads={workers}, backend={}): {} tasks in {}",
        kx.name(),
        fit.metrics.tasks_run,
        fmt_secs(dml_wall)
    );

    // ---- 4. estimates vs truth ------------------------------------------
    let host: Arc<dyn KernelExec> = backend_by_name("host")?;
    let ictx = RayContext::inline();
    let sub = subsample(&ds, 20_000.min(n)); // baselines are single-node
    let t_meta = Instant::now();
    let s = metalearners::s_learner(&ictx, host.clone(), &sub, 1e-3, 512)?;
    let t = metalearners::t_learner(&ictx, host.clone(), &sub, 1e-3, 512)?;
    let x = metalearners::x_learner(&ictx, host.clone(), &sub, 1e-3, 512)?;
    let aipw = dr::fit(&ictx, host.clone(), &sub, 5, 1e-3, 0.01, 512, 3)?;
    let meta_wall = t_meta.elapsed().as_secs_f64();

    let mut tbl = Table::new(
        "[4] estimator comparison (truth: ATE = 1.000)",
        &["estimator", "ATE", "95% CI", "abs err"],
    );
    tbl.row(vec![
        "LinearDML (distributed)".into(),
        format!("{:.4}", fit.ate.value),
        format!("[{:.3}, {:.3}]", fit.ate.ci_lo, fit.ate.ci_hi),
        format!("{:.4}", (fit.ate.value - 1.0).abs()),
    ]);
    tbl.row(vec![
        "AIPW (doubly robust)".into(),
        format!("{:.4}", aipw.ate.value),
        format!("[{:.3}, {:.3}]", aipw.ate.ci_lo, aipw.ate.ci_hi),
        format!("{:.4}", (aipw.ate.value - 1.0).abs()),
    ]);
    for (name, est) in [("S-learner", s.ate), ("T-learner", t.ate), ("X-learner", x.ate)] {
        tbl.row(vec![
            name.into(),
            format!("{est:.4}"),
            "-".into(),
            format!("{:.4}", (est - 1.0).abs()),
        ]);
    }
    tbl.print();
    println!("    (meta/DR baselines on a 20k subsample: {})", fmt_secs(meta_wall));

    // CATE curve
    let mut cate_err = 0.0f64;
    for x0 in [-2.0f32, -1.0, 0.0, 1.0, 2.0] {
        cate_err += ((fit.predict_cate(&[x0]) - (1.0 + 0.5 * x0)) as f64).abs();
    }
    println!("    CATE mean |err| over x0 grid: {:.4}", cate_err / 5.0);

    // ---- 5. refutation suite --------------------------------------------
    let refute_ds = subsample(&ds, 10_000.min(n));
    let host2 = host.clone();
    let estimator = move |d: &CausalDataset| -> nexus::Result<f64> {
        let cfg = CrossfitConfig {
            cv: 3,
            lam_y: 1e-3,
            lam_t: 1e-3,
            irls_iters: 4,
            block: 512,
            d_pad: (d.d() + 1).next_power_of_two().max(8),
            d_real: d.d(),
            seed: 5,
            stratified: true,
            reuse_suffstats: false,
        };
        let ctx = RayContext::inline();
        Ok(dml::fit_with(&ctx, host2.clone(), &CostModel::default(), d, &cfg, 0, 1)?
            .ate
            .value)
    };
    let t5 = Instant::now();
    let results = refute::run_all(&refute_ds, &estimator, 99)?;
    let mut rt = Table::new("[5] refutation suite", &["test", "original", "refuted", "verdict"]);
    for r in &results {
        rt.row(vec![
            r.name.into(),
            format!("{:.4}", r.original_ate),
            format!("{:.4}", r.refuted_ate),
            if r.passed { "PASS".into() } else { "FAIL".into() },
        ]);
    }
    rt.print();
    println!("    refuters ran in {}", fmt_secs(t5.elapsed().as_secs_f64()));

    // ---- 6. serving ------------------------------------------------------
    let model = CateModel::from_dml(&fit, 256, 16);
    let mut router = Router::new(
        model,
        host.clone(),
        BatchPolicy::default(),
        RoutingPolicy::PowerOfTwo,
        2,
    )?;
    let mut rng = Pcg32::new(2024);
    let t6 = Instant::now();
    let n_req = 5000;
    for _ in 0..n_req {
        router.enqueue(vec![rng.normal_f32()])?;
    }
    router.drain()?;
    let serve_wall = t6.elapsed().as_secs_f64();
    let st = router.stats();
    println!(
        "[6] serving: {n_req} CATE requests across {} replicas in {} ({:.0} req/s, {} batches, mean size {:.1}, p99 {:.2}ms)",
        router.alive_replicas(),
        fmt_secs(serve_wall),
        n_req as f64 / serve_wall,
        st.batches,
        st.mean_batch_size(),
        st.latency.p99() * 1e3
    );

    // ---- 7. cluster economics --------------------------------------------
    let cluster = ClusterConfig::default();
    let sim = RayContext::sim(cluster.clone(), false);
    let m = dml::fit_dry(&sim, &cost, n, &ccfg, 2)?;
    let seq = RayContext::sim(
        ClusterConfig { nodes: 1, slots_per_node: 1, ..cluster.clone() },
        false,
    );
    let ms = dml::fit_dry(&seq, &cost, n, &ccfg, 2)?;
    // warm-pool autoscaling (Ray keeps pre-booted workers): boot ~ 0,
    // idle timeout proportional to the schedule
    let auto = autoscaler::replay(
        &sim.gantt(),
        &AutoscalePolicy {
            max_nodes: cluster.nodes,
            slots_per_node: cluster.slots_per_node,
            idle_timeout: (m.makespan * 0.05).max(1e-3),
            boot_time: 0.0,
            min_nodes: 1,
        },
        cluster.dollars_per_node_hour,
    );
    println!(
        "[7] simulated 5-node cluster: makespan {} (sequential {}) => {:.1}x speedup",
        fmt_secs(m.makespan),
        fmt_secs(ms.makespan),
        ms.makespan / m.makespan
    );
    println!(
        "    cost: fixed cluster ${:.4} | autoscaled ${:.4} | peak nodes {}",
        m.cost_dollars, auto.dollars_at, auto.peak_nodes
    );

    println!("\n=== end-to-end complete ===");
    Ok(())
}

fn subsample(ds: &CausalDataset, k: usize) -> CausalDataset {
    if k >= ds.n() {
        return ds.clone();
    }
    let idx: Vec<usize> = (0..k).collect(); // deterministic prefix
    CausalDataset {
        x: ds.x.gather_rows(&idx),
        t: idx.iter().map(|&i| ds.t[i]).collect(),
        y: idx.iter().map(|&i| ds.y[i]).collect(),
        true_cate: idx.iter().map(|&i| ds.true_cate[i]).collect(),
        true_propensity: idx.iter().map(|&i| ds.true_propensity[i]).collect(),
        config: ds.config.clone(),
    }
}
