//! Quickstart: the fundamental problem of causal inference (paper
//! Table 1) and a 30-second Double-ML estimate on the paper's synthetic
//! DGP.
//!
//!     cargo run --release --offline --example quickstart

use std::sync::Arc;

use nexus::bench_support::Table;
use nexus::causal::dml;
use nexus::data::synth::{generate, SynthConfig};
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::HostBackend;

fn main() -> nexus::Result<()> {
    // ---- Table 1: we only ever observe ONE potential outcome per unit --
    let ds = generate(&SynthConfig { n: 6, d: 2, ..Default::default() });
    let mut t1 = Table::new(
        "Table 1 — fundamental problem of causal inference",
        &["unit", "T", "Y (observed)", "Y(0)", "Y(1)"],
    );
    for i in 0..ds.n() {
        let treated = ds.t[i] > 0.5;
        let y = ds.y[i];
        t1.row(vec![
            format!("{i}"),
            format!("{}", ds.t[i] as u8),
            format!("{y:+.2}"),
            if treated { "?".into() } else { format!("{y:+.2}") },
            if treated { format!("{y:+.2}") } else { "?".into() },
        ]);
    }
    t1.print();
    println!("\nEvery '?' is a counterfactual: identification assumptions");
    println!("(consistency, SUTVA, overlap, unconfoundedness) + DML fill the gap.\n");

    // ---- 30-second DML on the paper's §5.1 DGP ------------------------
    // y = (1 + 0.5 x0) T + f(x) + eps  =>  true ATE = 1, CATE = 1 + 0.5 x0
    let ds = generate(&SynthConfig { n: 10_000, d: 10, ..Default::default() });
    println!(
        "dataset: n={} d={} treated share={:.2} true ATE={:.3}",
        ds.n(),
        ds.d(),
        ds.treated_share(),
        ds.true_ate()
    );

    let ccfg = CrossfitConfig {
        cv: 5,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 5,
        block: 256,
        d_pad: 16,
        d_real: 10,
        seed: 42,
        stratified: true,
        reuse_suffstats: false,
    };
    let ctx = RayContext::threads(4); // the DML_Ray path
    let fit = dml::fit_with(
        &ctx,
        Arc::new(HostBackend),
        &CostModel::default(),
        &ds,
        &ccfg,
        1,
        2,
    )?;

    println!(
        "\nLinearDML: ATE = {:.4} ± {:.4}  (95% CI [{:.4}, {:.4}])",
        fit.ate.value, fit.ate.se, fit.ate.ci_lo, fit.ate.ci_hi
    );
    println!("theta = {:?}  (truth: [1.0, 0.5])", fit.theta);
    let mut t2 = Table::new("CATE(x0) vs truth", &["x0", "predicted", "truth"]);
    for x0 in [-2.0f32, -1.0, 0.0, 1.0, 2.0] {
        t2.row(vec![
            format!("{x0:+.1}"),
            format!("{:+.3}", fit.predict_cate(&[x0])),
            format!("{:+.3}", 1.0 + 0.5 * x0),
        ]);
    }
    t2.print();
    let m = &fit.metrics;
    println!(
        "\nexecutor: {} tasks across 4 workers, busy {:.2}s",
        m.tasks_run, m.busy_secs
    );
    Ok(())
}
