//! Fault tolerance demo: Ray-style lineage recovery under injected
//! failures, in both executors.
//!
//!   a) thread pool: 30% of task attempts crash — the cross-fitting DML
//!      estimate still completes, bit-identical to the failure-free run
//!   b) thread pool: objects are dropped after completion — lineage
//!      re-executes producers on demand
//!   c) simulated cluster: a whole node dies mid-run — tasks re-queue,
//!      lost objects reconstruct, the schedule stretches but finishes
//!
//!     cargo run --release --offline --example fault_tolerance

use std::sync::Arc;

use nexus::bench_support::fmt_secs;
use nexus::config::ClusterConfig;
use nexus::data::synth::{generate, SynthConfig};
use nexus::models::cost::CostModel;
use nexus::models::crossfit::CrossfitConfig;
use nexus::causal::dml;
use nexus::raylet::api::RayContext;
use nexus::raylet::fault::FaultPlan;
use nexus::raylet::payload::Payload;
use nexus::runtime::backend::HostBackend;

fn main() -> nexus::Result<()> {
    let ds = generate(&SynthConfig { n: 5000, d: 6, ..Default::default() });
    let ccfg = CrossfitConfig {
        cv: 3,
        lam_y: 1e-3,
        lam_t: 1e-3,
        irls_iters: 4,
        block: 256,
        d_pad: 8,
        d_real: 6,
        seed: 1,
        stratified: true,
        reuse_suffstats: false,
    };
    let cost = CostModel::default();
    let kx = Arc::new(HostBackend);

    // ---- baseline: no failures -----------------------------------------
    let clean_ctx = RayContext::threads(4);
    let clean = dml::fit_with(&clean_ctx, kx.clone(), &cost, &ds, &ccfg, 1, 2)?;
    println!("[baseline] ATE = {:.4}, {} tasks, 0 failures", clean.ate.value, clean.metrics.tasks_run);

    // ---- a) 30% attempt crash rate ---------------------------------------
    let faulty_ctx = RayContext::threads_with_faults(4, FaultPlan::with_prob(0.30, 20, 777));
    let faulty = dml::fit_with(&faulty_ctx, kx.clone(), &cost, &ds, &ccfg, 1, 2)?;
    let fm = &faulty.metrics;
    println!(
        "[a] crash-prob 30%: ATE = {:.4} | retries={} permanent-failures={}",
        faulty.ate.value, fm.retries, fm.failed
    );
    assert_eq!(clean.theta, faulty.theta, "estimates must survive crashes unchanged");
    assert!(fm.retries > 50, "expected many retries, got {}", fm.retries);
    println!("    => bit-identical theta despite {} re-executions", fm.retries);

    // ---- b) object loss + lineage reconstruction -------------------------
    let ctx = RayContext::threads(2);
    let base = ctx.submit(
        "expensive-base",
        vec![],
        0.0,
        Arc::new(|_: &[&Payload]| Ok(Payload::Scalar(21.0))),
    );
    let derived = ctx.submit(
        "derived",
        vec![base],
        0.0,
        Arc::new(|a: &[&Payload]| Ok(Payload::Scalar(a[0].as_scalar()? * 2.0))),
    );
    assert_eq!(ctx.get(&derived)?.as_scalar()?, 42.0);
    ctx.drop_object(&base)?;
    ctx.drop_object(&derived)?;
    let recovered = ctx.get(&derived)?.as_scalar()?;
    println!("[b] dropped BOTH objects; lineage recomputed derived = {recovered}");
    assert_eq!(recovered, 42.0);

    // ---- c) node failure on the simulated cluster -------------------------
    let cluster = ClusterConfig { nodes: 4, slots_per_node: 4, ..Default::default() };
    let healthy = RayContext::sim(cluster.clone(), true);
    let h = dml::fit_with(&healthy, kx.clone(), &cost, &ds, &ccfg, 1, 2)?;

    // node 2 dies shortly into the run
    let t_fail = h.metrics.makespan * 0.3;
    let wounded = RayContext::sim_with_faults(
        cluster.clone(),
        true,
        FaultPlan { node_failures: vec![(t_fail, 2)], ..FaultPlan::none() },
    );
    let w = dml::fit_with(&wounded, kx.clone(), &cost, &ds, &ccfg, 1, 2)?;
    println!(
        "[c] node 2 died at t={}: makespan {} -> {} (+{:.0}%), retries={}, reconstructions={}",
        fmt_secs(t_fail),
        fmt_secs(h.metrics.makespan),
        fmt_secs(w.metrics.makespan),
        100.0 * (w.metrics.makespan / h.metrics.makespan - 1.0),
        w.metrics.retries,
        w.metrics.reconstructions
    );
    assert_eq!(h.theta, w.theta, "node failure must not change the estimate");
    assert!(w.metrics.makespan >= h.metrics.makespan);
    println!("    => identical estimate on 3 surviving nodes");

    println!("\nfault-tolerance demo complete: all invariants held");
    Ok(())
}
