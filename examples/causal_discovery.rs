//! Distributed causal discovery (paper §6 future scope): PC algorithm
//! over a linear-Gaussian SEM, with the correlation pass and every
//! CI-test batch running as raylet tasks.
//!
//!     cargo run --release --offline --example causal_discovery

use std::sync::Arc;

use nexus::bench_support::Table;
use nexus::causal::discovery::{self, PcConfig};
use nexus::data::matrix::Matrix;
use nexus::raylet::api::RayContext;
use nexus::runtime::backend::HostBackend;
use nexus::util::rng::Pcg32;

fn main() -> nexus::Result<()> {
    // ground-truth DAG (a plausible marketing funnel):
    //   0 ad_spend -> 1 visits -> 2 signups -> 4 revenue
    //   3 seasonality -> 1 visits,  3 -> 4 revenue
    let d = 5;
    let names = ["ad_spend", "visits", "signups", "seasonality", "revenue"];
    let edges = [
        (0usize, 1usize, 0.8f32),
        (1, 2, 0.9),
        (2, 4, 0.7),
        (3, 1, 0.5),
        (3, 4, 0.4),
    ];
    println!("true DAG:");
    for &(p, c, w) in &edges {
        println!("  {} -> {} (w={w})", names[p], names[c]);
    }

    // sample the SEM
    let n = 20_000;
    let mut rng = Pcg32::new(42);
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        for v in 0..d {
            let mut val = rng.normal_f32();
            for &(p, c, w) in &edges {
                if c == v {
                    val += w * x.get(i, p);
                }
            }
            x.set(i, v, val);
        }
    }

    // distributed PC
    let ctx = RayContext::threads(4);
    let corr = discovery::correlation_matrix(&ctx, Arc::new(HostBackend), &x, 4096)?;
    let pc_cfg = PcConfig { alpha: 0.01, max_level: 3, parallel: true };
    let g = discovery::pc(&ctx, &corr, n, &pc_cfg)?;
    let m = ctx.metrics();

    let mut tbl = Table::new(
        "PC output (CPDAG)",
        &["edge", "orientation", "in true DAG?"],
    );
    for (i, j, kind, flipped) in g.edges() {
        let (a, b) = if flipped { (j, i) } else { (i, j) };
        let label = match kind {
            discovery::EdgeKind::Directed => format!("{} -> {}", names[a], names[b]),
            discovery::EdgeKind::Undirected => format!("{} -- {}", names[a], names[b]),
        };
        let truth = edges
            .iter()
            .any(|&(p, c, _)| (p == i && c == j) || (p == j && c == i));
        tbl.row(vec![
            label,
            format!("{kind:?}"),
            if truth { "yes".into() } else { "NO (false edge)".into() },
        ]);
    }
    tbl.print();
    println!(
        "\n{} edges recovered (truth has {}); {} raylet tasks across the correlation pass + CI batches",
        g.n_edges(),
        edges.len(),
        m.tasks_run
    );
    Ok(())
}
