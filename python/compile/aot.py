"""AOT entry point: lower every L2 graph at every shipped shape to HLO text.

Interchange format is HLO *text*, NOT `lowered.compile().serialize()`:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
image's xla_extension 0.5.1 (the version the published `xla` 0.1.6 rust
crate binds) rejects with `proto.id() <= INT_MAX`.  The text parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example/README.md.

Every graph is emitted in two impl families:
  pallas  -- the L1 pallas kernels (interpret=True) inside the graph; this
             is the TPU-shaped code path and what python/tests validates
  jnp     -- identical math through plain jnp contractions; on the CPU PJRT
             backend this compiles to native dot loops and is the fast path
             the rust coordinator uses by default (ablation: bench_ablation
             compares the two)

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Writes  <name>.hlo.txt per artifact plus manifest.json.
"""

import argparse
import contextlib
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import gram as gram_kernel
from compile.kernels import ref
from compile.kernels import residual as residual_kernel

# Shipped shape grid.  d includes the intercept column and zero padding;
# the paper's workload (d ~ 500 covariates) maps to d = 512.
BLOCK_B = (256, 4096)
DIMS_D = (16, 64, 512)
DIMS_P = (1, 2, 8)
SOLVE_D = sorted(set(DIMS_D) | set(DIMS_P))


@contextlib.contextmanager
def _jnp_impl():
    """Swap the L1 pallas kernels for their jnp oracles while lowering."""
    saved = (gram_kernel.gram, gram_kernel.cross, residual_kernel.residualize)
    gram_kernel.gram = lambda x, **kw: ref.gram(x)
    gram_kernel.cross = lambda x, z, **kw: ref.cross(x, z)
    residual_kernel.residualize = lambda *a, **kw: ref.residualize(*a)
    try:
        yield
    finally:
        gram_kernel.gram, gram_kernel.cross, residual_kernel.residualize = saved


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple so rust sees
    one tuple output regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shapes(specs):
    return [list(s.shape) for s in specs]


def lower_one(kind, dims, impl):
    fn, spec_builder = model.GRAPHS[kind]
    specs = spec_builder(*dims)
    ctx = _jnp_impl() if impl == "jnp" else contextlib.nullcontext()
    with ctx:
        lowered = jax.jit(lambda *a: fn(*a)).lower(*specs)
        text = to_hlo_text(lowered)
    outs = jax.tree_util.tree_leaves(getattr(lowered, "out_info", None))
    out_shapes = [list(o.shape) for o in outs] or None
    return text, _shapes(specs), out_shapes


def artifact_plan():
    """Every (kind, dims) pair shipped.  dims is (b, d), (d,), or (b, p)."""
    plan = []
    for b in BLOCK_B:
        for d in DIMS_D:
            for kind in ("gram", "predict", "predict_proba", "irls", "residual"):
                plan.append((kind, (b, d)))
        for p in DIMS_P:
            for kind in ("final_moments", "final_score"):
                plan.append((kind, (b, p)))
    for d in SOLVE_D:
        plan.append(("solve", (d,)))
    return plan


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--impls", default="pallas,jnp",
        help="comma list of impl families to emit (pallas, jnp)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    impls = [s.strip() for s in args.impls.split(",") if s.strip()]
    entries = []
    for kind, dims in artifact_plan():
        fams = impls if kind != "solve" else ["jnp"]  # solve has no kernel
        for impl in fams:
            dim_tag = "_".join(str(v) for v in dims)
            name = f"{kind}_{dim_tag}_{impl}"
            text, in_shapes, out_shapes = lower_one(kind, dims, impl)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            entries.append({
                "name": name,
                "kind": kind,
                "impl": impl,
                "file": fname,
                "dims": list(dims),
                "inputs": in_shapes,
                "outputs": out_shapes,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"  wrote {fname:40s} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "block_b": list(BLOCK_B),
        "dims_d": list(DIMS_D),
        "dims_p": list(DIMS_P),
        "solve_d": list(SOLVE_D),
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
