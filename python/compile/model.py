"""L2: the DML compute graphs, written in jax, calling the L1 pallas kernels.

Each function here is one *static-shape* logical step of the NEXUS
estimation pipeline.  `aot.py` lowers every (function, shape) pair once to
HLO text; the rust coordinator (rust/src/runtime) loads, compiles and
executes them from the request path -- python never runs at run time.

Shape conventions (all f32):
  b      rows per block (the coordinator streams row blocks)
  d      padded covariate width (constant-1 intercept column included by
         the coordinator; padding columns are zero so they are inert in
         every Gram/solve below as long as lam_diag > 0 on padded entries)
  p      final-stage feature width (phi = [1] for ATE, [1, x_het...] CATE)

The statistical contract of each graph is documented in kernels/ref.py,
which pytest uses as the allclose oracle.
"""

import jax
import jax.numpy as jnp

from compile.kernels import gram as gram_kernel
from compile.kernels import residual as residual_kernel


# --------------------------------------------------------------------------
# Nuisance model_y: ridge regression via streaming sufficient statistics.
# --------------------------------------------------------------------------

def gram_block(x, y, mask):
    """Partial (X'X, X'y, n) for one masked row block.

    mask is 0/1 per row; padded (invalid) rows contribute nothing because
    mask^2 == mask.  The X'X product runs through the L1 pallas kernel.
    """
    xm = x * mask[:, None]
    g = gram_kernel.gram(xm)
    b = gram_kernel.cross(xm, (y * mask)[:, None])[:, 0]
    return g, b, jnp.sum(mask)


def ridge_solve(g, b, lam_diag):
    """beta = (G + diag(lam))^-1 b via Gauss-Jordan elimination.

    NOT `jax.scipy.linalg.solve`: on CPU that lowers to a LAPACK
    typed-FFI custom call (`API_VERSION_TYPED_FFI`) which the image's
    xla_extension 0.5.1 rejects at compile time.  Gauss-Jordan in a
    `fori_loop` lowers to pure HLO (dots + dynamic slices), is exact in
    d steps, and needs no pivoting because the ridge-regularized system
    is symmetric positive definite (padding columns carry lam = 1).

    lam_diag is a vector so the coordinator can (a) not penalize the
    intercept column and (b) strongly penalize padding columns, keeping
    the padded system well conditioned.
    """
    d = b.shape[0]
    a = g + jnp.diag(lam_diag)
    aug = jnp.concatenate([a, b[:, None]], axis=1)  # d x (d+1)

    def step(k, aug):
        pivot = aug[k, k]
        row_k = aug[k] / pivot
        factors = aug[:, k].at[k].set(0.0)
        aug = aug - factors[:, None] * row_k[None, :]
        return aug.at[k].set(row_k)

    aug = jax.lax.fori_loop(0, d, step, aug)
    return aug[:, d]


def predict_block(x, beta):
    """yhat = X beta for one row block."""
    return x @ beta


# --------------------------------------------------------------------------
# Nuisance model_t: logistic regression via blocked Newton/IRLS.
# --------------------------------------------------------------------------

def logistic_irls_block(x, t, mask, beta):
    """Partial Newton statistics (H, c, nll) at the current beta.

    H = X'WX (via the pallas gram kernel on sqrt(W)-scaled rows),
    c = X'Wz with z the IRLS working response.  The coordinator sums the
    partials over blocks and calls ridge_solve(H, c, lam) for the step.
    """
    eta = x @ beta
    p = jax.nn.sigmoid(eta)
    w = jnp.maximum(p * (1.0 - p), 1e-6)
    wm = w * mask
    z = eta + (t - p) / w
    xs = x * jnp.sqrt(wm)[:, None]
    h = gram_kernel.gram(xs)
    c = gram_kernel.cross(x, (wm * z)[:, None])[:, 0]
    eps = 1e-7
    ll = t * jnp.log(p + eps) + (1.0 - t) * jnp.log(1.0 - p + eps)
    return h, c, -jnp.sum(ll * mask)


def predict_proba_block(x, beta):
    """p = sigmoid(X beta) for one row block."""
    return jax.nn.sigmoid(x @ beta)


# --------------------------------------------------------------------------
# Residualization (the orthogonalization step) -- fused L1 kernel.
# --------------------------------------------------------------------------

def residual_block(x, y, t, beta_y, beta_t):
    """(y - X b_y, t - sigmoid(X b_t)) in one pass over X."""
    return residual_kernel.residualize(x, y, t, beta_y, beta_t)


# --------------------------------------------------------------------------
# Orthogonal final stage (EconML LinearDML estimating equation).
# --------------------------------------------------------------------------

def final_stage_moments(y_res, t_res, phi, mask):
    """Partial normal equations of the residual-on-residual regression:

        theta = argmin sum_i (y~_i - t~_i * phi_i' theta)^2
        M = sum t~^2 phi phi'        v = sum t~ y~ phi
    """
    tphi = phi * (t_res * mask)[:, None]
    m = gram_kernel.gram(tphi)
    v = gram_kernel.cross(tphi, (y_res)[:, None])[:, 0]
    return m, v


def final_stage_score(y_res, t_res, phi, theta, mask):
    """Partial HC1 'meat' S = sum psi psi', psi = (y~ - t~ phi'theta) t~ phi."""
    e = (y_res - t_res * (phi @ theta)) * t_res * mask
    psi = phi * e[:, None]
    return gram_kernel.gram(psi)


# --------------------------------------------------------------------------
# Registry used by aot.py: name -> (fn, arg-spec builder).
# aot.py instantiates each entry at every (b, d) / (d,) / (b, p) it emits.
# --------------------------------------------------------------------------

F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


GRAPHS = {
    # kind: (fn, lambda dims -> [input specs], doc)
    "gram": (
        lambda x, y, mask: gram_block(x, y, mask),
        lambda b, d: [_s(b, d), _s(b), _s(b)],
    ),
    "solve": (
        lambda g, v, lam: ridge_solve(g, v, lam),
        lambda d: [_s(d, d), _s(d), _s(d)],
    ),
    "predict": (
        lambda x, beta: predict_block(x, beta),
        lambda b, d: [_s(b, d), _s(d)],
    ),
    "predict_proba": (
        lambda x, beta: predict_proba_block(x, beta),
        lambda b, d: [_s(b, d), _s(d)],
    ),
    "irls": (
        lambda x, t, mask, beta: logistic_irls_block(x, t, mask, beta),
        lambda b, d: [_s(b, d), _s(b), _s(b), _s(d)],
    ),
    "residual": (
        lambda x, y, t, by, bt: residual_block(x, y, t, by, bt),
        lambda b, d: [_s(b, d), _s(b), _s(b), _s(d), _s(d)],
    ),
    "final_moments": (
        lambda yr, tr, phi, mask: final_stage_moments(yr, tr, phi, mask),
        lambda b, p: [_s(b), _s(b), _s(b, p), _s(b)],
    ),
    "final_score": (
        lambda yr, tr, phi, theta, mask: final_stage_score(yr, tr, phi, theta, mask),
        lambda b, p: [_s(b), _s(b), _s(b, p), _s(p), _s(b)],
    ),
}
