"""L1 Pallas kernel: fused residualization.

One pass over the row blocks of X produces BOTH out-of-fold residuals that
Double ML needs:

    y_res = y - X @ beta_y                    (outcome nuisance, ridge)
    t_res = t - sigmoid(X @ beta_t)           (propensity nuisance, logistic)

Fusing the two matvecs means X is read from HBM once instead of twice --
the residualization step is bandwidth-bound (2*b*d FLOPs on b*d bytes), so
this halves its run time on real hardware.  interpret=True on this image
(see kernels/gram.py for why).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_kernel(x_ref, y_ref, t_ref, by_ref, bt_ref, yres_ref, tres_ref):
    x = x_ref[...]
    yres_ref[...] = y_ref[...] - x @ by_ref[...]
    tres_ref[...] = t_ref[...] - jax.nn.sigmoid(x @ bt_ref[...])


def _pick_tile(dim: int, preferred: int) -> int:
    t = min(dim, preferred)
    while dim % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("block_b",))
def residualize(x, y, t, beta_y, beta_t, *, block_b: int = 256):
    """(f32[b,d], f32[b], f32[b], f32[d], f32[d]) -> (y_res f32[b], t_res f32[b])."""
    b, d = x.shape
    bt = _pick_tile(b, block_b)
    grid = (b // bt,)
    return pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), x.dtype),
            jax.ShapeDtypeStruct((b,), x.dtype),
        ],
        interpret=True,
    )(x, y, t, beta_y, beta_t)
