"""Pure-jnp oracles for every L1 kernel and L2 graph.

pytest asserts allclose(kernel, ref) across a hypothesis sweep of shapes
and dtypes -- this file is the single source of numerical truth for the
python side; rust/src/linalg is the equivalent oracle on the rust side.
"""

import jax
import jax.numpy as jnp


# ----- L1 oracles -----------------------------------------------------------

def gram(x):
    return x.T @ x


def cross(x, z):
    return x.T @ z


def residualize(x, y, t, beta_y, beta_t):
    return y - x @ beta_y, t - jax.nn.sigmoid(x @ beta_t)


# ----- L2 oracles (the statistical math, stated plainly) --------------------

def gram_block(x, y, mask):
    """Masked partial sufficient statistics for ridge: (X'X, X'y, n)."""
    xm = x * mask[:, None]
    return xm.T @ xm, xm.T @ (y * mask), jnp.sum(mask)


def ridge_solve(g, b, lam_diag):
    return jnp.linalg.solve(g + jnp.diag(lam_diag), b)


def predict_block(x, beta):
    return x @ beta


def logistic_irls_block(x, t, mask, beta):
    """Masked partial Newton/IRLS statistics for logistic regression.

    Returns (H, c, loss) with H = X'WX, c = X'W z (z the working response),
    so the coordinator's Newton step is beta' = solve(H + lam I, c).
    """
    eta = x @ beta
    p = jax.nn.sigmoid(eta)
    w = jnp.maximum(p * (1.0 - p), 1e-6)
    wm = w * mask
    z = eta + (t - p) / w
    xs = x * jnp.sqrt(wm)[:, None]
    h = xs.T @ xs
    c = x.T @ (wm * z)
    eps = 1e-7
    ll = t * jnp.log(p + eps) + (1.0 - t) * jnp.log(1.0 - p + eps)
    return h, c, -jnp.sum(ll * mask)


def final_stage_moments(y_res, t_res, phi, mask):
    """Orthogonal final stage: M = sum t~^2 phi phi', v = sum t~ y~ phi."""
    tphi = phi * (t_res * mask)[:, None]
    return tphi.T @ tphi, tphi.T @ y_res


def final_stage_score(y_res, t_res, phi, theta, mask):
    """HC-robust meat: S = sum psi psi', psi = (y~ - t~ phi'theta) t~ phi."""
    e = (y_res - t_res * (phi @ theta)) * t_res * mask
    psi = phi * e[:, None]
    return psi.T @ psi
