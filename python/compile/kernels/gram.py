"""L1 Pallas kernel: tiled Gram-matrix accumulation.

The FLOP hot spot of NEXUS's nuisance fits is the Gram matrix X^T X over
d ~ 500 covariates (ridge fit, logistic IRLS, and the orthogonal final
stage all reduce to it).  On TPU this is an MXU-shaped reduction; the
BlockSpec below expresses the HBM->VMEM schedule:

  grid = (d/dt, d/dt, b/bt)                 # (i, j, k)
  x1 panel (bt, dt) at (k, i)  -- VMEM      # left operand, transposed use
  x2 panel (bt, dt) at (k, j)  -- VMEM      # right operand
  out tile (dt, dt) at (i, j)  -- VMEM accumulator, revisited over k

dt = 128 matches the MXU systolic array edge; bt = 128 keeps the working
set (2 * 128*128 + 128*128 f32 = 192 KiB) far inside a 16 MiB VMEM budget,
leaving room for double buffering (see DESIGN.md section 8).

MUST run with interpret=True on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls.  Numerics are identical either way; real-TPU
performance is estimated from the BlockSpec in EXPERIMENTS.md section Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x1_ref, x2_ref, o_ref):
    """One (i, j, k) grid step: o[i, j] += x1[k, i]^T @ x2[k, j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # jnp.dot on (dt, bt) @ (bt, dt) tiles -> MXU matmul on real hardware.
    o_ref[...] += jnp.dot(
        x1_ref[...].T, x2_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (tiles must be exact)."""
    t = min(dim, preferred)
    while dim % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("block_d", "block_b"))
def gram(x, *, block_d: int = 128, block_b: int = 128):
    """X^T X via the tiled Pallas kernel.  x: f32[b, d] -> f32[d, d]."""
    b, d = x.shape
    dt = _pick_tile(d, block_d)
    bt = _pick_tile(b, block_b)
    grid = (d // dt, d // dt, b // bt)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, dt), lambda i, j, k: (k, i)),
            pl.BlockSpec((bt, dt), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((dt, dt), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), x.dtype),
        interpret=True,
    )(x, x)


@functools.partial(jax.jit, static_argnames=("block_d", "block_b"))
def cross(x, z, *, block_d: int = 128, block_b: int = 128):
    """X^T Z for x: f32[b, d], z: f32[b, e] -> f32[d, e] (same schedule)."""
    b, d = x.shape
    _, e = z.shape
    dt = _pick_tile(d, block_d)
    et = _pick_tile(e, block_d)
    bt = _pick_tile(b, block_b)
    grid = (d // dt, e // et, b // bt)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, dt), lambda i, j, k: (k, i)),
            pl.BlockSpec((bt, et), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((dt, et), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, e), x.dtype),
        interpret=True,
    )(x, z)
