"""L2 graphs: statistical contracts, not just allclose-vs-oracle.

Checks that the streamed/blocked formulations reproduce closed-form
whole-data answers -- the exact property the rust coordinator relies on
when it sums partial statistics from distributed tasks.
"""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _data(n=400, d=8, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (n, d), jnp.float32)
    beta = jnp.linspace(-1, 1, d, dtype=jnp.float32)
    y = x @ beta + 0.1 * jax.random.normal(k2, (n,), jnp.float32)
    t = (jax.random.uniform(k3, (n,)) < jax.nn.sigmoid(x[:, 0])).astype(
        jnp.float32)
    return x, y, t, beta


# ---------------------------------------------------------------------------
# ridge: blocked sufficient statistics == whole-data closed form
# ---------------------------------------------------------------------------

def test_gram_blocks_sum_to_whole_data_gram():
    x, y, _, _ = _data(400, 8)
    mask = jnp.ones((100,), jnp.float32)
    g_sum = jnp.zeros((8, 8))
    b_sum = jnp.zeros((8,))
    n_sum = 0.0
    for i in range(4):
        g, b, n = model.gram_block(x[i * 100:(i + 1) * 100], y[i * 100:(i + 1) * 100], mask)
        g_sum, b_sum, n_sum = g_sum + g, b_sum + b, n_sum + n
    assert_allclose(g_sum, x.T @ x, rtol=1e-4, atol=1e-3)
    assert_allclose(b_sum, x.T @ y, rtol=1e-4, atol=1e-3)
    assert n_sum == 400.0


def test_partial_block_mask():
    """A short final block padded with garbage rows + mask=0 is exact."""
    x, y, _, _ = _data(64, 4, seed=1)
    pad_x = jnp.concatenate([x, 99.0 * jnp.ones((36, 4), jnp.float32)])
    pad_y = jnp.concatenate([y, 99.0 * jnp.ones((36,), jnp.float32)])
    mask = jnp.concatenate([jnp.ones((64,)), jnp.zeros((36,))])
    g, b, n = model.gram_block(pad_x, pad_y, mask)
    assert_allclose(g, x.T @ x, rtol=1e-4, atol=1e-3)
    assert_allclose(b, x.T @ y, rtol=1e-4, atol=1e-3)
    assert n == 64.0


def test_ridge_solve_recovers_coefficients():
    x, y, _, beta = _data(2000, 8)
    mask = jnp.ones((2000,), jnp.float32)
    g, b, _ = model.gram_block(x, y, mask)
    beta_hat = model.ridge_solve(g, b, 1e-3 * jnp.ones((8,)))
    assert_allclose(beta_hat, beta, atol=0.05)


def test_ridge_solve_padding_columns_inert():
    """Zero-padded columns with big lam stay ~0 and do not disturb others."""
    x, y, _, _ = _data(500, 4, seed=2)
    xpad = jnp.concatenate([x, jnp.zeros((500, 4), jnp.float32)], axis=1)
    mask = jnp.ones((500,), jnp.float32)
    g, b, _ = model.gram_block(xpad, y, mask)
    lam = jnp.concatenate([1e-3 * jnp.ones((4,)), 1e6 * jnp.ones((4,))])
    beta = model.ridge_solve(g, b, lam)
    g0, b0, _ = model.gram_block(x, y, mask)
    beta0 = model.ridge_solve(g0, b0, 1e-3 * jnp.ones((4,)))
    assert_allclose(beta[:4], beta0, rtol=1e-3, atol=1e-4)
    assert_allclose(beta[4:], jnp.zeros((4,)), atol=1e-6)


# ---------------------------------------------------------------------------
# logistic IRLS: blocked Newton converges to the MLE
# ---------------------------------------------------------------------------

def test_logistic_irls_converges_to_mle():
    n, d = 4000, 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (n, d), jnp.float32)
    beta_true = jnp.array([1.0, -0.5, 0.25, 0.0], jnp.float32)
    p = jax.nn.sigmoid(x @ beta_true)
    t = (jax.random.uniform(k2, (n,)) < p).astype(jnp.float32)
    mask = jnp.ones((n,), jnp.float32)

    beta = jnp.zeros((d,), jnp.float32)
    losses = []
    for _ in range(8):
        h_sum = jnp.zeros((d, d))
        c_sum = jnp.zeros((d,))
        loss = 0.0
        for i in range(0, n, 1000):
            h, c, l = model.logistic_irls_block(
                x[i:i + 1000], t[i:i + 1000], mask[i:i + 1000], beta)
            h_sum, c_sum, loss = h_sum + h, c_sum + c, loss + l
        beta = model.ridge_solve(h_sum, c_sum, 1e-4 * jnp.ones((d,)))
        losses.append(float(loss))
    # Newton converged: last two losses nearly equal, loss decreased overall
    assert losses[-1] <= losses[0]
    assert abs(losses[-1] - losses[-2]) < 1e-2
    assert_allclose(beta, beta_true, atol=0.15)
    # first-order condition: sum (t - p) x ~ 0 at the MLE
    grad = x.T @ (t - jax.nn.sigmoid(x @ beta))
    assert float(jnp.max(jnp.abs(grad))) < 0.5


def test_irls_block_matches_ref():
    x, y, t, _ = _data(200, 8, seed=4)
    mask = (jnp.arange(200) < 150).astype(jnp.float32)
    beta = 0.1 * jnp.ones((8,), jnp.float32)
    got = model.logistic_irls_block(x, t, mask, beta)
    want = ref.logistic_irls_block(x, t, mask, beta)
    for g, w in zip(got, want):
        assert_allclose(g, w, rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# final stage: orthogonal moments reproduce the residual-on-residual OLS
# ---------------------------------------------------------------------------

def test_final_stage_equals_direct_ols():
    n, p = 600, 2
    k = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(k, 3)
    t_res = jax.random.normal(k1, (n,), jnp.float32)
    phi = jnp.concatenate(
        [jnp.ones((n, 1)), jax.random.normal(k2, (n, 1))], axis=1)
    theta_true = jnp.array([1.0, 0.5], jnp.float32)
    y_res = t_res * (phi @ theta_true) + 0.05 * jax.random.normal(k3, (n,))
    mask = jnp.ones((n,), jnp.float32)

    m, v = model.final_stage_moments(y_res, t_res, phi, mask)
    theta = model.ridge_solve(m, v, jnp.zeros((p,)) + 1e-8)
    # direct weighted least squares answer
    a = phi * t_res[:, None]
    theta_direct = jnp.linalg.lstsq(a, y_res)[0]
    assert_allclose(theta, theta_direct, rtol=1e-3, atol=1e-3)
    assert_allclose(theta, theta_true, atol=0.05)


def test_final_score_matches_ref_and_is_psd():
    n, p = 300, 2
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    t_res = jax.random.normal(k1, (n,), jnp.float32)
    phi = jnp.concatenate([jnp.ones((n, 1)),
                           jax.random.normal(k2, (n, 1))], axis=1)
    y_res = 2.0 * t_res + 0.1 * jax.random.normal(k1, (n,))
    theta = jnp.array([2.0, 0.0], jnp.float32)
    mask = jnp.ones((n,), jnp.float32)
    s = model.final_stage_score(y_res, t_res, phi, theta, mask)
    s_ref = ref.final_stage_score(y_res, t_res, phi, theta, mask)
    assert_allclose(s, s_ref, rtol=2e-4, atol=2e-4)
    w = np.linalg.eigvalsh(np.asarray(s))
    assert w.min() > -1e-4


def test_residual_block_produces_orthogonal_residuals():
    """After residualizing on the TRUE nuisances, residuals are ~orthogonal
    to X -- the Neyman orthogonality property DML rests on."""
    n, d = 5000, 4
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(k1, (n, d), jnp.float32)
    beta_t = jnp.array([0.8, 0.0, -0.4, 0.2], jnp.float32)
    p = jax.nn.sigmoid(x @ beta_t)
    t = (jax.random.uniform(k2, (n,)) < p).astype(jnp.float32)
    beta_y = jnp.array([1.0, 0.5, 0.0, -1.0], jnp.float32)
    y = x @ beta_y + t + 0.1 * jax.random.normal(k3, (n,))
    yr, tr = model.residual_block(x, y, t, beta_y, beta_t)
    # t-residual has mean ~0 and is uncorrelated with each x_j
    assert abs(float(jnp.mean(tr))) < 0.03
    corr = jnp.abs(x.T @ tr) / n
    assert float(jnp.max(corr)) < 0.05
