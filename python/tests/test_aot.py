"""AOT path: every shipped graph lowers to parseable HLO text and the
manifest is self-consistent.  (The rust side re-validates numerics against
rust/src/linalg at run time; python/tests/test_kernels.py validates the
pallas kernels against the jnp oracle.)"""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.mark.parametrize("kind,dims", [
    ("gram", (256, 16)),
    ("predict", (256, 16)),
    ("predict_proba", (256, 16)),
    ("irls", (256, 16)),
    ("residual", (256, 16)),
    ("final_moments", (256, 2)),
    ("final_score", (256, 2)),
    ("solve", (16,)),
])
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_lower_each_kind(kind, dims, impl):
    if kind == "solve" and impl == "pallas":
        pytest.skip("solve has no kernel family")
    text, in_shapes, _ = aot.lower_one(kind, dims, impl)
    assert "ENTRY" in text and "ROOT" in text
    # the entry layout declares one f32 parameter per input spec
    header = text.split("->")[0]
    assert header.count("f32[") == len(in_shapes)


def test_pallas_and_jnp_families_differ_for_gram():
    """interpret-mode pallas lowers to loop HLO; jnp lowers to a plain dot.
    If these were identical the ablation bench would be meaningless."""
    t_pallas, _, _ = aot.lower_one("gram", (256, 16), "pallas")
    t_jnp, _, _ = aot.lower_one("gram", (256, 16), "jnp")
    assert t_pallas != t_jnp
    assert "dot(" in t_jnp


def test_plan_covers_every_kind_and_shape():
    plan = aot.artifact_plan()
    kinds = {k for k, _ in plan}
    assert kinds == set(model.GRAPHS.keys())
    for b in aot.BLOCK_B:
        for d in aot.DIMS_D:
            assert ("gram", (b, d)) in plan
    for d in aot.SOLVE_D:
        assert ("solve", (d,)) in plan


def test_manifest_if_built():
    """When `make artifacts` has run, the manifest must index real files."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    names = set()
    for e in manifest["artifacts"]:
        assert e["name"] not in names, "duplicate artifact name"
        names.add(e["name"])
        path = os.path.join(art, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
