"""L1 pallas kernels vs the pure-jnp oracle (kernels/ref.py).

hypothesis sweeps shapes (including non-multiple-of-tile sizes, which the
tile picker must handle) and value distributions; assert_allclose is the
correctness bar for everything the rust runtime will execute.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile.kernels import gram, ref, residual

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# gram kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 16, 64, 100, 128, 256]),
    d=st.sampled_from([1, 2, 5, 16, 33, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_gram_matches_ref(b, d, seed):
    x = _rand(seed, b, d)
    assert_allclose(gram.gram(x), ref.gram(x), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([4, 32, 128, 200]),
    d=st.sampled_from([3, 16, 64]),
    e=st.sampled_from([1, 2, 8]),
    seed=st.integers(0, 2**16),
)
def test_cross_matches_ref(b, d, e, seed):
    x = _rand(seed, b, d)
    z = _rand(seed + 1, b, e)
    assert_allclose(gram.cross(x, z), ref.cross(x, z), rtol=2e-4, atol=2e-4)


def test_gram_large_block_paper_shape():
    """The paper's workload shape: d=512 (500 covariates padded)."""
    x = _rand(7, 1024, 512, scale=0.5)
    assert_allclose(gram.gram(x), ref.gram(x), rtol=3e-4, atol=3e-3)


def test_gram_is_symmetric_psd():
    x = _rand(11, 300, 40)
    g = np.asarray(gram.gram(x))
    assert_allclose(g, g.T, rtol=1e-6, atol=1e-6)
    w = np.linalg.eigvalsh(g)
    assert w.min() > -1e-3  # PSD up to f32 roundoff


def test_gram_zero_rows_are_inert():
    """Masked (zeroed) rows must not change the Gram -- the padding contract."""
    x = _rand(13, 64, 16)
    xpad = jnp.concatenate([x, jnp.zeros((64, 16), jnp.float32)], axis=0)
    assert_allclose(gram.gram(xpad), gram.gram(x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_d,block_b", [(8, 16), (16, 128), (128, 64)])
def test_gram_tiling_invariance(block_d, block_b):
    """The answer must not depend on the BlockSpec tiling."""
    x = _rand(17, 128, 32)
    base = ref.gram(x)
    assert_allclose(
        gram.gram(x, block_d=block_d, block_b=block_b), base,
        rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused residualization kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 7, 64, 128, 300]),
    d=st.sampled_from([1, 4, 16, 50]),
    seed=st.integers(0, 2**16),
)
def test_residual_matches_ref(b, d, seed):
    x = _rand(seed, b, d)
    y = _rand(seed + 1, b)
    t = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (b,)) > 0.5).astype(
        jnp.float32)
    by = _rand(seed + 3, d, scale=0.3)
    bt = _rand(seed + 4, d, scale=0.3)
    yr, tr = residual.residualize(x, y, t, by, bt)
    yr_ref, tr_ref = ref.residualize(x, y, t, by, bt)
    assert_allclose(yr, yr_ref, rtol=1e-4, atol=1e-4)
    assert_allclose(tr, tr_ref, rtol=1e-4, atol=1e-4)


def test_residual_propensity_in_unit_interval():
    x = _rand(1, 256, 16, scale=0.2)
    t = jnp.ones((256,), jnp.float32)
    _, tr = residual.residualize(
        x, jnp.zeros((256,)), t, jnp.zeros((16,)), _rand(2, 16, scale=0.2))
    # t=1 minus a probability => residual in [0, 1]; moderate eta => interior
    assert float(jnp.min(tr)) >= 0.0
    assert float(jnp.max(tr)) <= 1.0
    assert 0.0 < float(jnp.mean(tr)) < 1.0


def test_tile_picker_exact_divisors():
    assert gram._pick_tile(512, 128) == 128
    assert gram._pick_tile(100, 128) == 100
    assert gram._pick_tile(96, 64) == 48
    assert gram._pick_tile(7, 4) == 1
    assert gram._pick_tile(1, 128) == 1
